//! Megatron-style training simulator (§8.2).
//!
//! Iteration time = analytic compute (6·P·T FLOPs over achieved per-GPU
//! FLOPs) + exposed communication. Communication times come from two
//! sources matching the paper's methodology split:
//! * testbed scale (2 servers): the fluid-flow event simulator via
//!   [`CommWorld`] process groups — TP AllReduce on intra-server groups,
//!   PP SendRecv on stage-pair groups, DP AllReduce on replica groups
//!   (see [`training_groups`]) — collectives actually execute, failures
//!   migrate, and each class of traffic sees exactly its own group's
//!   fault domain;
//! * SimAI scale (4–128 servers): the α-β analytic models of
//!   [`crate::schedule::planner`] (running a 512-rank event-level ring per
//!   Monte-Carlo sample would be wasteful and adds nothing at this
//!   abstraction level);
//! * SimAI scale, compiled ([`simai_compiled_iteration`]): the fluid-flow
//!   simulator driven through the communicator's compile path at 4–32
//!   servers — the scale sweep that validates the analytic arm against
//!   real compiled schedules (and exercises the plan cache at scale).

use crate::baselines::adapcc::AdapCcModel;
use crate::ccl::{CommGroup, CommWorld, ParallelLayout, StrategyChoice};
use crate::collectives::exec::{FaultAction, FaultEvent, ObserveOptions};
use crate::fabric::SwitchFaultEvent;
use crate::collectives::{CollKind, PhantomPlane, RealPlane};
use crate::config::{GpuComputeConfig, Preset};
use crate::scenario::IterOutcome;
use crate::schedule::{choose_strategy, ring_time, PlanInput, Strategy};

/// Transformer model shapes (decoder-only GPT family, as in the paper).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: &'static str,
    pub params: f64,
    pub layers: usize,
    pub hidden: usize,
    pub seq: usize,
}

impl ModelConfig {
    pub fn gpt_2_7b() -> Self {
        ModelConfig { name: "GPT-2.7B", params: 2.7e9, layers: 32, hidden: 2560, seq: 2048 }
    }
    pub fn gpt_7b() -> Self {
        ModelConfig { name: "GPT-7B", params: 7.0e9, layers: 32, hidden: 4096, seq: 2048 }
    }
    pub fn gpt_13b() -> Self {
        ModelConfig { name: "GPT-13B", params: 13.0e9, layers: 40, hidden: 5120, seq: 2048 }
    }
    pub fn gpt_175b() -> Self {
        ModelConfig { name: "GPT-175B", params: 175.0e9, layers: 96, hidden: 12288, seq: 2048 }
    }
}

/// Parallelism layout.
#[derive(Debug, Clone)]
pub struct ParallelConfig {
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub global_batch: usize,
    pub microbatch: usize,
}

impl ParallelConfig {
    pub fn n_gpus(&self) -> usize {
        self.dp * self.tp * self.pp
    }
}

/// Failure-handling method under test (the Figure 7 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMethod {
    NoFailure,
    R2AllReduce,
    R2Balance,
    R2HotRepair,
    AdapCc,
    VanillaNccl,
}

/// One simulated training result.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub method: TrainMethod,
    pub tokens_per_sec: f64,
    /// Relative overhead vs the no-failure run of the same config.
    pub overhead: f64,
    pub iter_time: f64,
    pub compute_time: f64,
    pub comm_time: f64,
}

/// Per-iteration communication volumes (bytes).
#[derive(Debug, Clone)]
pub struct CommVolumes {
    /// DP gradient AllReduce per rank (bf16 grads of the DP shard).
    pub dp_allreduce: u64,
    /// PP activations per microbatch per boundary (bf16), both directions.
    pub pp_p2p: u64,
    /// One TP activations AllReduce (bf16 microbatch activations); Megatron
    /// issues 4 per transformer layer (2 forward, 2 backward).
    pub tp_allreduce: u64,
    /// TP AllReduce invocations per microbatch (4 per layer of the stage).
    pub tp_calls_per_micro: usize,
    pub n_microbatches: usize,
}

pub fn comm_volumes(model: &ModelConfig, par: &ParallelConfig) -> CommVolumes {
    let grad_bytes = (model.params / (par.tp * par.pp) as f64 * 2.0) as u64;
    let micro_tokens = par.microbatch * model.seq;
    let act_bytes = (micro_tokens * model.hidden * 2) as u64;
    CommVolumes {
        dp_allreduce: grad_bytes,
        pp_p2p: act_bytes,
        tp_allreduce: act_bytes,
        tp_calls_per_micro: 4 * (model.layers / par.pp).max(1),
        n_microbatches: par.global_batch / (par.microbatch * par.dp).max(1),
    }
}

/// Compute time of one iteration (per pipeline flush): 6·P·T FLOPs spread
/// over the GPUs.
pub fn compute_time(model: &ModelConfig, par: &ParallelConfig, gpu: &GpuComputeConfig) -> f64 {
    let tokens = (par.global_batch * model.seq) as f64;
    6.0 * model.params * tokens / (par.n_gpus() as f64 * gpu.flops_per_gpu)
}

// ---------------------------------------------------------------------
// Testbed mode: event-simulated collectives on the 2×8 H100 topology.
// ---------------------------------------------------------------------

/// The communicator groups a 3D-parallel training job creates at startup:
/// tensor-parallel groups, pipeline stage-*pair* groups (the communicator
/// each PP boundary SendRecv runs on) and data-parallel replica groups.
/// Exposed so integration tests can inspect exactly which rank sets the
/// training simulator drives its collectives on.
pub struct TrainingGroups {
    pub tp: Vec<CommGroup>,
    pub pp: Vec<CommGroup>,
    pub dp: Vec<CommGroup>,
}

/// Build the process groups of a parallel layout on `world` (Megatron
/// order: tp innermost → contiguous, hence intra-server for tp ≤ 8).
pub fn training_groups(world: &CommWorld, par: &ParallelConfig) -> TrainingGroups {
    let layout = ParallelLayout::new(par.tp, par.dp, par.pp);
    TrainingGroups {
        tp: world.tp_groups(&layout),
        pp: world.pp_pairs(&layout),
        dp: world.dp_groups(&layout),
    }
}

/// Build the process groups of a parallel layout over the *active*
/// membership of an elastic world: layout ranks are re-mapped through the
/// surviving-GPU re-ranking (`CommWorld::active_ranks`), so every group
/// excludes shrunk-away servers. With full membership this is bit-identical
/// to [`training_groups`].
pub fn training_groups_elastic(world: &CommWorld, par: &ParallelConfig) -> TrainingGroups {
    let layout = ParallelLayout::new(par.tp, par.dp, par.pp);
    TrainingGroups {
        tp: world.tp_groups_elastic(&layout),
        pp: world.pp_pairs_elastic(&layout),
        dp: world.dp_groups_elastic(&layout),
    }
}

/// DP-shrink (or re-expand) a parallel config onto `n_active_ranks`
/// surviving GPUs: tp and pp are structural and fixed, dp absorbs the whole
/// membership change. The global batch is preserved — surviving replicas
/// each process more microbatches rather than shrinking the batch.
pub fn dp_shrink(par: &ParallelConfig, n_active_ranks: usize) -> ParallelConfig {
    assert!(
        n_active_ranks % (par.tp * par.pp) == 0 && n_active_ranks > 0,
        "active ranks {n_active_ranks} not divisible by tp*pp = {}",
        par.tp * par.pp
    );
    ParallelConfig { dp: n_active_ranks / (par.tp * par.pp), ..par.clone() }
}

/// The iteration's dominant cross-server collective — where scenario fault
/// scripts land mid-flight: the DP gradient AllReduce when there is data
/// parallelism, else the PP boundary SendRecv, else the TP AllReduce
/// (degenerate single-server case). Side collectives carry 1/8 of the main
/// volume each.
pub fn scenario_main_collective<'g>(
    groups: &'g TrainingGroups,
    par: &ParallelConfig,
    bytes_per_rank: u64,
) -> (&'g CommGroup, CollKind, u64) {
    if par.dp > 1 {
        (&groups.dp[0], CollKind::AllReduce, bytes_per_rank)
    } else if par.pp > 1 {
        (&groups.pp[0], CollKind::SendRecv, (bytes_per_rank / 8).max(1))
    } else {
        (&groups.tp[0], CollKind::AllReduce, bytes_per_rank)
    }
}

/// Cross-rank collective launches per scenario training iteration — the
/// count the recovery arms charge AdapCC's per-collective heartbeat to.
/// Mirrors [`scenario_training_iteration`] exactly: 4 TP AllReduces when
/// `tp > 1`, 2 PP boundary crossings when `pp > 1 && dp > 1`, plus the
/// dominant main collective.
pub fn scenario_collectives_per_iteration(tp: usize, dp: usize, pp: usize) -> usize {
    (if tp > 1 { 4 } else { 0 }) + (if pp > 1 && dp > 1 { 2 } else { 0 }) + 1
}

/// One scenario-driven training iteration over live process groups: TP
/// AllReduce (4 calls) and PP boundary SendRecv (2 crossings) are timed
/// under the standing plan-time health state, then the dominant
/// cross-server collective runs with `script` injected mid-flight. When
/// `verify_data` is set and the main collective is an AllReduce, it runs
/// over a real data plane and the result is compared against the healthy
/// elementwise sum — the losslessness invariant of the scenario harness.
#[allow(clippy::too_many_arguments)]
pub fn scenario_training_iteration(
    world: &CommWorld,
    groups: &TrainingGroups,
    par: &ParallelConfig,
    bytes_per_rank: u64,
    choice: StrategyChoice,
    script: Vec<FaultEvent>,
    switch_script: Vec<SwitchFaultEvent>,
    observe: ObserveOptions,
    verify_data: bool,
) -> IterOutcome {
    let crash_outcome = |time: f64| IterOutcome {
        time,
        crashed: true,
        migrations: 0,
        retransmitted_bytes: 0,
        wasted_bytes: 0,
        wire_bytes: 0,
        strategy: Strategy::Standard,
        timeline: Vec::new(),
        lossless: None,
        events_popped: 0,
        domains_touched: 0,
        resident_resources: 0,
        telemetry: None,
    };
    let side_bytes = (bytes_per_rank / 8).max(1);
    let mut time = 0.0;
    if par.tp > 1 {
        match groups.tp[0].time_collective(CollKind::AllReduce, side_bytes, choice) {
            Some(t) => time += 4.0 * t,
            None => return crash_outcome(time),
        }
    }
    if par.pp > 1 && par.dp > 1 {
        match groups.pp[0].time_collective(CollKind::SendRecv, side_bytes, choice) {
            Some(t) => time += 2.0 * t,
            None => return crash_outcome(time),
        }
    }
    let (main, kind, main_bytes) = scenario_main_collective(groups, par, bytes_per_rank);
    let verify = verify_data && kind == CollKind::AllReduce && main.n_ranks() > 1;
    // Element count divisible by channels × group size, as the exact
    // data-plane split requires.
    let elems = if verify { world.channels() * main.n_ranks() * 8 } else { 0 };
    let (_, strategy) = main.compile(kind, main_bytes, elems, choice);
    let (rep, lossless) = if verify {
        let mut plane = RealPlane::new(world.topo().n_gpus(), elems);
        plane.fill_pattern();
        let expected = plane.expected_allreduce_over(main.ranks());
        let rep = main.run_observed(
            kind,
            main_bytes,
            choice,
            script,
            switch_script,
            observe,
            &mut plane,
            elems,
        );
        let verdict =
            if rep.crashed { None } else { Some(plane.ranks_equal(main.ranks(), &expected)) };
        (rep, verdict)
    } else {
        let rep = main.run_observed(
            kind,
            main_bytes,
            choice,
            script,
            switch_script,
            observe,
            &mut PhantomPlane,
            0,
        );
        (rep, None)
    };
    IterOutcome::from_report(rep, time, strategy, lossless)
}

/// Simulate one training configuration on the physical-testbed topology
/// with `failed_nics` NICs down on server 0 (Figure 7). Each class of
/// traffic runs on its actual process group: TP AllReduce on intra-server
/// groups, PP SendRecv on stage-pair groups, DP AllReduce on replica
/// groups — so a NIC failure degrades exactly the groups whose servers it
/// touches.
pub fn testbed_training(
    preset: &Preset,
    model: &ModelConfig,
    par: &ParallelConfig,
    method: TrainMethod,
    failed_nics: usize,
) -> TrainResult {
    assert_eq!(par.n_gpus(), 16, "testbed is 16 GPUs");
    let vols = comm_volumes(model, par);
    let t_compute = compute_time(model, par, &preset.compute);

    // Vanilla NCCL crashes outright; AdapCC cannot run TP/PP at all.
    if failed_nics > 0 {
        if method == TrainMethod::VanillaNccl {
            return zero_result(method, t_compute);
        }
        if method == TrainMethod::AdapCc && (par.tp > 1 || par.pp > 1) {
            // Removing a rank violates TP/PP partitioning (§8.2).
            return zero_result(method, t_compute);
        }
    }

    let mut world = CommWorld::new(preset, preset.topo.nics_per_server);
    let effective_failures = if method == TrainMethod::NoFailure { 0 } else { failed_nics };
    for n in 0..effective_failures {
        world.note_failure(n, FaultAction::FailNic);
    }
    let groups = training_groups(&world, par);

    let choice = match method {
        TrainMethod::NoFailure | TrainMethod::VanillaNccl => StrategyChoice::Auto,
        TrainMethod::R2AllReduce => StrategyChoice::Force(Strategy::R2AllReduce),
        TrainMethod::R2Balance => StrategyChoice::Force(Strategy::Balance),
        TrainMethod::R2HotRepair => StrategyChoice::HotRepairOnly,
        TrainMethod::AdapCc => StrategyChoice::Auto, // healthy ranks, std schedule
    };

    let mut t_comm = 0.0;
    let mut capacity_factor = 1.0;
    if par.dp > 1 && par.tp * par.pp == 1 {
        // Pure DP: gradient AllReduce over the (single, world-spanning)
        // replica group each iteration.
        let dp_group = &groups.dp[0];
        let t_ar = match method {
            TrainMethod::AdapCc if effective_failures > 0 => {
                let adapcc = AdapCcModel::default();
                // AdapCC excludes the failed GPU: compute capacity shrinks,
                // collective runs over remaining ranks on healthy NICs.
                capacity_factor = adapcc.capacity_factor(par.n_gpus(), effective_failures);
                let t = dp_group
                    .time_collective(CollKind::AllReduce, vols.dp_allreduce, StrategyChoice::Auto)
                    .expect("allreduce");
                t + adapcc.per_collective_overhead()
            }
            _ => dp_group
                .time_collective(CollKind::AllReduce, vols.dp_allreduce, choice)
                .expect("allreduce"),
        };
        t_comm += t_ar;
    } else {
        // TP activations AllReduce on the tensor-parallel group (NVLink;
        // worst case: the group living on the degraded server 0).
        if par.tp > 1 {
            let t_tp = groups.tp[0]
                .time_collective(CollKind::AllReduce, vols.tp_allreduce, choice)
                .expect("tp allreduce");
            t_comm +=
                (vols.tp_calls_per_micro * vols.n_microbatches.max(1)) as f64 * t_tp;
        }
        // PP boundary exchange on the stage-pair group: fwd+bwd
        // activations+grad-activations for every microbatch.
        if par.pp > 1 {
            let t_pp = groups.pp[0]
                .time_collective(CollKind::SendRecv, vols.pp_p2p, choice)
                .expect("pp sendrecv");
            t_comm += 2.0 * vols.n_microbatches.max(1) as f64 * t_pp;
        }
        if par.dp > 1 {
            // Gradient AllReduce on each replica group; replicas reduce
            // concurrently but the iteration waits for the slowest — time
            // the group whose servers include the failure domain.
            t_comm += groups.dp[0]
                .time_collective(CollKind::AllReduce, vols.dp_allreduce, choice)
                .expect("dp allreduce");
        } else {
            // Embedding/grad-norm allreduce once per iteration (ties the
            // first and last stage: world scope).
            t_comm += world
                .world_group()
                .time_collective(CollKind::AllReduce, (model.hidden * 4) as u64, choice)
                .unwrap_or(0.0);
        }
    }

    finish(method, model, par, t_compute / capacity_factor, t_comm, preset)
}

// ---------------------------------------------------------------------
// SimAI mode, compiled: event-simulated collectives at cluster scale.
// ---------------------------------------------------------------------

/// One SimAI-scale training iteration whose DP gradient AllReduce executes
/// a *real compiled schedule* on the fluid-flow simulator — the scale arm
/// of the evaluation exercising the same compile path (epoch-keyed health,
/// plan cache, generic ring/tree builders) as the testbed, instead of the
/// α-β analytic shortcut of [`simai_iteration`]. `failed_nics` NICs are
/// taken down on server 0 before the iteration starts.
pub fn simai_compiled_iteration(
    n_servers: usize,
    channels: usize,
    model: &ModelConfig,
    par: &ParallelConfig,
    method: TrainMethod,
    failed_nics: usize,
) -> TrainResult {
    let preset = Preset::simai(n_servers);
    assert_eq!(
        par.n_gpus(),
        preset.topo.n_servers * preset.topo.gpus_per_server,
        "parallel layout must fill the cluster"
    );
    let vols = comm_volumes(model, par);
    let t_compute = compute_time(model, par, &preset.compute);
    // Same infeasibility rules as the testbed arm: vanilla NCCL crashes
    // outright, and AdapCC cannot drop a rank out of a TP/PP partition.
    if failed_nics > 0 {
        if method == TrainMethod::VanillaNccl {
            return zero_result(method, t_compute);
        }
        if method == TrainMethod::AdapCc && (par.tp > 1 || par.pp > 1) {
            return zero_result(method, t_compute);
        }
    }

    let channels = channels.min(preset.topo.nics_per_server).max(1);
    let mut world = CommWorld::new(&preset, channels);
    let effective = if method == TrainMethod::NoFailure { 0 } else { failed_nics };
    for n in 0..effective {
        world.note_failure(n, FaultAction::FailNic);
    }
    let choice = match method {
        TrainMethod::NoFailure | TrainMethod::VanillaNccl | TrainMethod::AdapCc => {
            StrategyChoice::Auto
        }
        TrainMethod::R2AllReduce => StrategyChoice::Force(Strategy::R2AllReduce),
        TrainMethod::R2Balance => StrategyChoice::Force(Strategy::Balance),
        TrainMethod::R2HotRepair => StrategyChoice::HotRepairOnly,
    };
    // The DP replica group spans the whole cluster at this layout (tp
    // intra-node, dp across servers): the gradient AllReduce runs on it.
    let mut t_comm = world
        .world_group()
        .time_collective(CollKind::AllReduce, vols.dp_allreduce, choice)
        .expect("dp allreduce");
    // Mirror the testbed arm's AdapCC accounting: the reconfiguration
    // overhead lands on the collective, the shrunken-cluster capacity
    // factor on compute only (the collective already paid the degraded
    // network inside the fluid simulation).
    let mut capacity_factor = 1.0;
    if method == TrainMethod::AdapCc && effective > 0 {
        let adapcc = AdapCcModel::default();
        t_comm += adapcc.per_collective_overhead();
        capacity_factor = adapcc.capacity_factor(par.n_gpus(), effective);
    }
    finish(method, model, par, t_compute / capacity_factor, t_comm, &preset)
}

// ---------------------------------------------------------------------
// SimAI mode: analytic α-β collectives at cluster scale.
// ---------------------------------------------------------------------

/// Analytic AllReduce time for a strategy under a degradation vector.
pub fn analytic_allreduce_time(
    input: &PlanInput,
    bytes: f64,
    method: TrainMethod,
) -> f64 {
    match method {
        TrainMethod::NoFailure => {
            let healthy = PlanInput { rem: vec![1.0; input.n], ..input.clone() };
            ring_time(CollKind::AllReduce, &healthy, bytes, true)
        }
        TrainMethod::R2HotRepair | TrainMethod::VanillaNccl => {
            ring_time(CollKind::AllReduce, input, bytes, false)
        }
        TrainMethod::R2Balance => ring_time(CollKind::AllReduce, input, bytes, true),
        TrainMethod::AdapCc => {
            // Healthy subset at full speed + reconfiguration overhead.
            let healthy = PlanInput { rem: vec![1.0; input.n], ..input.clone() };
            ring_time(CollKind::AllReduce, &healthy, bytes, true)
                + AdapCcModel::default().per_collective_overhead()
        }
        TrainMethod::R2AllReduce => {
            let nr = input.n_ranks() as f64;
            let steps_alpha = 2.0 * (nr - 1.0) * input.alpha;
            if input.degraded_servers() == 0 {
                return ring_time(CollKind::AllReduce, input, bytes, true);
            }
            // Per-server, per-direction wire-volume model of the level
            // decomposition (Fig 5 accounting, duplex-aware): completion is
            // governed by the busiest server relative to its remaining
            // capacity. Member servers of level k carry the ring volume
            // 2(N_k−1)/N_k·f_k each direction (plus the broadcast walk,
            // f_k, through their leads); excluded servers inject their
            // contribution (f_k tx) and receive the result (f_k rx) —
            // injection and delivery ride opposite directions, so each
            // direction grows by only f_k (the 2D → 2D−YD saving of §5.2).
            let levels = crate::schedule::plan_levels(&input.rem);
            let mut volume = vec![0.0f64; input.n]; // per-direction, ×D
            for (k, lv) in levels.iter().enumerate() {
                let m = (lv.servers.len() * input.g) as f64;
                let ring_vol = 2.0 * (m - 1.0) / m * lv.fraction;
                for s in 0..input.n {
                    if lv.servers.contains(&s) {
                        // Ring volume; levels k>0 also forward the tailored
                        // broadcast walk through their leads.
                        volume[s] += ring_vol + if k > 0 { lv.fraction * 0.5 } else { 0.0 };
                    } else {
                        volume[s] += lv.fraction; // inject ‖ deliver (duplex)
                    }
                }
            }
            let t_bytes = (0..input.n)
                .map(|s| volume[s] / (input.rem[s] * input.server_bw))
                .fold(0.0_f64, f64::max)
                * bytes;
            // Never worse than plain Balance (the planner would fall back).
            let t_bal = ring_time(CollKind::AllReduce, input, bytes, true);
            (steps_alpha + t_bytes).min(t_bal)
        }
    }
}

/// One SimAI-scale training iteration (pure DP over servers; TP intra).
pub fn simai_iteration(
    model: &ModelConfig,
    par: &ParallelConfig,
    gpu: &GpuComputeConfig,
    input: &PlanInput,
    method: TrainMethod,
) -> TrainResult {
    let vols = comm_volumes(model, par);
    let t_compute = compute_time(model, par, gpu);
    let t_comm = analytic_allreduce_time(input, vols.dp_allreduce as f64, method);
    let preset = Preset::simai(input.n);
    let mut r = finish(method, model, par, t_compute, t_comm, &preset);
    if method == TrainMethod::AdapCc {
        let adapcc = AdapCcModel::default();
        let f = adapcc.capacity_factor(par.n_gpus(), input.degraded_servers());
        r.iter_time /= f;
        r.tokens_per_sec *= f;
    }
    r
}

/// Strategy auto-selection honoring the planner (used by scale sweeps).
pub fn auto_method(input: &PlanInput, bytes: f64) -> TrainMethod {
    match choose_strategy(CollKind::AllReduce, input, bytes) {
        Strategy::Standard => TrainMethod::NoFailure,
        Strategy::Balance => TrainMethod::R2Balance,
        Strategy::R2AllReduce | Strategy::Recursive => TrainMethod::R2AllReduce,
    }
}

// ---------------------------------------------------------------------

fn zero_result(method: TrainMethod, t_compute: f64) -> TrainResult {
    TrainResult {
        method,
        tokens_per_sec: 0.0,
        overhead: f64::INFINITY,
        iter_time: f64::INFINITY,
        compute_time: t_compute,
        comm_time: f64::INFINITY,
    }
}

fn finish(
    method: TrainMethod,
    model: &ModelConfig,
    par: &ParallelConfig,
    t_compute: f64,
    t_comm: f64,
    preset: &Preset,
) -> TrainResult {
    // Exposed communication after overlap with compute.
    let exposed = t_comm * (1.0 - preset.compute.overlap_fraction);
    let iter = t_compute + exposed;
    let tokens = (par.global_batch * model.seq) as f64;
    TrainResult {
        method,
        tokens_per_sec: tokens / iter,
        overhead: 0.0, // filled by callers relative to their baseline
        iter_time: iter,
        compute_time: t_compute,
        comm_time: t_comm,
    }
}

/// Relative overhead helper.
pub fn overhead_vs(result: &TrainResult, baseline: &TrainResult) -> f64 {
    (result.iter_time - baseline.iter_time) / baseline.iter_time
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn dp16() -> ParallelConfig {
        ParallelConfig { dp: 16, tp: 1, pp: 1, global_batch: 256, microbatch: 1 }
    }

    fn tp8pp2() -> ParallelConfig {
        ParallelConfig { dp: 1, tp: 8, pp: 2, global_batch: 64, microbatch: 2 }
    }

    #[test]
    fn collectives_per_iteration_matches_iteration_structure() {
        // Pure DP: just the main DP AllReduce.
        assert_eq!(scenario_collectives_per_iteration(1, 16, 1), 1);
        // TP adds its 4 side AllReduces.
        assert_eq!(scenario_collectives_per_iteration(8, 2, 1), 5);
        // PP crossings only run alongside DP (the main is the PP SendRecv
        // otherwise).
        assert_eq!(scenario_collectives_per_iteration(8, 1, 2), 5);
        assert_eq!(scenario_collectives_per_iteration(8, 2, 2), 7);
    }

    #[test]
    fn figure7_dp16_ordering() {
        // Fig 7(a): NoFailure > R2-AllReduce > Balance > HotRepair > AdapCC,
        // vanilla = 0.
        let preset = Preset::testbed();
        let model = ModelConfig::gpt_2_7b();
        let par = dp16();
        let base = testbed_training(&preset, &model, &par, TrainMethod::NoFailure, 1);
        let r2 = testbed_training(&preset, &model, &par, TrainMethod::R2AllReduce, 1);
        let bal = testbed_training(&preset, &model, &par, TrainMethod::R2Balance, 1);
        let hot = testbed_training(&preset, &model, &par, TrainMethod::R2HotRepair, 1);
        let adapcc = testbed_training(&preset, &model, &par, TrainMethod::AdapCc, 1);
        let vanilla = testbed_training(&preset, &model, &par, TrainMethod::VanillaNccl, 1);
        assert!(vanilla.tokens_per_sec == 0.0);
        assert!(base.tokens_per_sec > r2.tokens_per_sec);
        assert!(r2.tokens_per_sec >= bal.tokens_per_sec, "r2 {} bal {}", r2.tokens_per_sec, bal.tokens_per_sec);
        assert!(bal.tokens_per_sec > hot.tokens_per_sec);
        assert!(hot.tokens_per_sec > adapcc.tokens_per_sec || overhead_vs(&adapcc, &base) > 0.05);
        // Headline: R²CCL-AllReduce < ~2% overhead; AdapCC worst.
        assert!(overhead_vs(&r2, &base) < 0.03, "r2 overhead {}", overhead_vs(&r2, &base));
        assert!(overhead_vs(&adapcc, &base) > overhead_vs(&bal, &base));
    }

    #[test]
    fn figure7_tp8pp2_adapcc_cannot_run() {
        let preset = Preset::testbed();
        let model = ModelConfig::gpt_13b();
        let par = tp8pp2();
        let adapcc = testbed_training(&preset, &model, &par, TrainMethod::AdapCc, 1);
        assert_eq!(adapcc.tokens_per_sec, 0.0);
        let base = testbed_training(&preset, &model, &par, TrainMethod::NoFailure, 1);
        let bal = testbed_training(&preset, &model, &par, TrainMethod::R2Balance, 1);
        let hot = testbed_training(&preset, &model, &par, TrainMethod::R2HotRepair, 1);
        // Balance < ~2% overhead; HotRepair worse than Balance.
        assert!(overhead_vs(&bal, &base) < 0.02, "balance overhead {}", overhead_vs(&bal, &base));
        assert!(overhead_vs(&hot, &base) >= overhead_vs(&bal, &base));
    }

    #[test]
    fn two_failures_still_low_overhead() {
        let preset = Preset::testbed();
        let model = ModelConfig::gpt_2_7b();
        let par = dp16();
        let base = testbed_training(&preset, &model, &par, TrainMethod::NoFailure, 2);
        let r2 = testbed_training(&preset, &model, &par, TrainMethod::R2AllReduce, 2);
        let o = overhead_vs(&r2, &base);
        assert!(o < 0.05, "two-failure overhead {o}");
    }

    #[test]
    fn simai_overhead_below_paper_bounds() {
        // Fig 8: R²-AllReduce < 1.5% overhead, Balance up to ~5% at scale.
        let model = ModelConfig::gpt_7b();
        for n in [4usize, 16, 64] {
            let par = ParallelConfig { dp: n * 4, tp: 2, pp: 1, global_batch: 512, microbatch: 1 };
            let gpu = GpuComputeConfig::a100();
            let mut input = PlanInput::uniform(n, 8, 25.0e9 * 8.0, 5e-6);
            input.rem[0] = 0.875; // one NIC down
            let base = simai_iteration(&model, &par, &gpu, &input, TrainMethod::NoFailure);
            let r2 = simai_iteration(&model, &par, &gpu, &input, TrainMethod::R2AllReduce);
            let bal = simai_iteration(&model, &par, &gpu, &input, TrainMethod::R2Balance);
            let o_r2 = overhead_vs(&r2, &base);
            let o_bal = overhead_vs(&bal, &base);
            assert!(o_r2 < 0.035, "n={n}: r2 overhead {o_r2}");
            assert!(o_bal >= o_r2 - 1e-9, "n={n}: bal {o_bal} r2 {o_r2}");
        }
    }

    #[test]
    fn simai_compiled_matches_analytic_ordering() {
        // The compiled (event-simulated) scale arm must reproduce the
        // analytic arm's qualitative shape: failure overhead is positive,
        // Balance bounds HotRepair from below, and everything completes on
        // a 4-server SimAI cluster driven through the real compile path.
        let model = ModelConfig::gpt_2_7b();
        let n = 4usize;
        let par = ParallelConfig { dp: n * 4, tp: 2, pp: 1, global_batch: 128, microbatch: 1 };
        let base = simai_compiled_iteration(n, 2, &model, &par, TrainMethod::NoFailure, 1);
        let bal = simai_compiled_iteration(n, 2, &model, &par, TrainMethod::R2Balance, 1);
        let hot = simai_compiled_iteration(n, 2, &model, &par, TrainMethod::R2HotRepair, 1);
        assert!(base.comm_time > 0.0 && base.iter_time.is_finite());
        let o_bal = overhead_vs(&bal, &base);
        let o_hot = overhead_vs(&hot, &base);
        assert!(o_bal >= 0.0, "balance overhead {o_bal}");
        assert!(o_hot >= o_bal - 1e-9, "hotrepair {o_hot} vs balance {o_bal}");
        let vanilla = simai_compiled_iteration(n, 2, &model, &par, TrainMethod::VanillaNccl, 1);
        assert_eq!(vanilla.tokens_per_sec, 0.0);
    }

    #[test]
    fn comm_ratio_grows_with_scale() {
        // Fig 8(d): fixed global batch → smaller per-GPU compute → larger
        // communication ratio at higher server counts.
        let model = ModelConfig::gpt_7b();
        let gpu = GpuComputeConfig::a100();
        let mut prev_ratio = 0.0;
        for n in [4usize, 16, 64] {
            let par = ParallelConfig { dp: n * 4, tp: 2, pp: 1, global_batch: 512, microbatch: 1 };
            let input = PlanInput::uniform(n, 8, 25.0e9 * 8.0, 5e-6);
            let r = simai_iteration(&model, &par, &gpu, &input, TrainMethod::NoFailure);
            let ratio = r.comm_time / (r.compute_time + r.comm_time);
            assert!(ratio > prev_ratio, "ratio should grow: {ratio} at n={n}");
            prev_ratio = ratio;
        }
    }

    #[test]
    fn compute_time_scales_inverse_gpus() {
        let m = ModelConfig::gpt_7b();
        let gpu = GpuComputeConfig::default();
        let p1 = ParallelConfig { dp: 8, tp: 1, pp: 1, global_batch: 256, microbatch: 1 };
        let p2 = ParallelConfig { dp: 16, tp: 1, pp: 1, global_batch: 256, microbatch: 1 };
        assert!((compute_time(&m, &p1, &gpu) / compute_time(&m, &p2, &gpu) - 2.0).abs() < 1e-9);
    }
}
