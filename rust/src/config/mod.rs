//! Configuration: failure-handling timing budgets, checkpoint-recovery cost
//! model, GPU compute model, and named cluster presets. All values carry the
//! paper's cited numbers as defaults and are overridable from the CLI.

use crate::topology::TopologyConfig;

/// Timing parameters of the failure-handling path. Defaults follow the
/// paper: detection drops "from minutes to milliseconds" via OOB
/// notification (§4.1); GPU memory registration "takes milliseconds per
/// buffer and RDMA connection setup tens of milliseconds" (§4.3,
/// Silberstein et al. 2016); migration latency stays "in the
/// low-millisecond range" with multi-registration.
#[derive(Debug, Clone)]
pub struct TimingConfig {
    /// Local CQ/QP error surfacing delay after the fault hits an in-flight
    /// operation (RDMA NICs retry autonomously before reporting).
    pub cq_error_delay: f64,
    /// One-way OOB (bootstrap network) notification latency.
    pub oob_notify: f64,
    /// OOB broadcast of a confirmed diagnosis to all ranks.
    pub oob_broadcast: f64,
    /// RTT of a zero-byte probe on a healthy path.
    pub probe_rtt: f64,
    /// Probe timeout used to declare a path dead.
    pub probe_timeout: f64,
    /// DMA-buffer rollback bookkeeping (rewind cursors, purge WRs).
    pub rollback_cost: f64,
    /// On-demand GPU buffer registration with one NIC (only paid when
    /// multi-registration is disabled — the ablation).
    pub lazy_reg_cost: f64,
    /// On-demand RDMA connection establishment (only without
    /// pre-established backup connections — the ablation).
    pub conn_setup_cost: f64,
    /// Interval of periodic reprobing for component recovery.
    pub reprobe_interval: f64,
    /// Chunk size of the transport (rollback granularity).
    pub chunk_bytes: u64,
    /// Capacity factor below which a bandwidth fluctuation is handled like
    /// a link failure: in-flight transfers hit transport timeouts (the
    /// paper's flapping / fluctuation-triggered detection) and migrate
    /// instead of crawling on the collapsed link. Factors at or above the
    /// threshold are plain degradations (CRC retries) and stay put.
    pub degrade_detect_threshold: f64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        TimingConfig {
            cq_error_delay: 1.0e-3,
            oob_notify: 0.5e-3,
            oob_broadcast: 1.0e-3,
            probe_rtt: 10.0e-6,
            probe_timeout: 2.0e-3,
            rollback_cost: 0.2e-3,
            lazy_reg_cost: 5.0e-3,
            conn_setup_cost: 30.0e-3,
            reprobe_interval: 1.0,
            chunk_bytes: 512 * 1024,
            degrade_detect_threshold: 0.05,
        }
    }
}

impl TimingConfig {
    /// End-to-end hot-repair latency with multi-registration and
    /// pre-established backups: detect locally, notify peer OOB,
    /// triangulate, roll back, resume. (No registration / connection setup
    /// on the recovery path.)
    pub fn hot_repair_latency(&self) -> f64 {
        self.cq_error_delay + self.oob_notify + self.probe_timeout + self.rollback_cost
    }

    /// The same path when buffers must be registered and connections
    /// established on demand (the paper's motivation for multi-registration).
    pub fn lazy_repair_latency(&self) -> f64 {
        self.hot_repair_latency() + self.lazy_reg_cost + self.conn_setup_cost
    }
}

/// Checkpoint-based recovery cost model (§2.2: detection 3–30 min,
/// isolation 9–14 min, checkpoint load 15–47 min, communicator rebuild
/// 17 s – 20 min; median total ≈ 68 min). The vanilla-NCCL baseline pays
/// this on every unhandled network failure.
#[derive(Debug, Clone)]
pub struct CheckpointCostModel {
    pub detection: f64,
    pub isolation: f64,
    pub reload: f64,
    pub rebuild: f64,
    /// Mean work lost since the last checkpoint (recomputed iterations).
    pub lost_work: f64,
}

impl Default for CheckpointCostModel {
    fn default() -> Self {
        // Midpoints of the paper's ranges; total ≈ 68 min with lost work.
        CheckpointCostModel {
            detection: 10.0 * 60.0,
            isolation: 11.0 * 60.0,
            reload: 25.0 * 60.0,
            rebuild: 5.0 * 60.0,
            lost_work: 17.0 * 60.0,
        }
    }
}

impl CheckpointCostModel {
    /// Total downtime of one checkpoint-restart recovery.
    pub fn total(&self) -> f64 {
        self.detection + self.isolation + self.reload + self.rebuild + self.lost_work
    }
}

/// Analytic GPU compute model used by the workload simulators: training
/// step FLOPs ≈ 6 · params · tokens (fwd+bwd), divided by achieved FLOPs.
#[derive(Debug, Clone)]
pub struct GpuComputeConfig {
    /// Achieved dense FLOPs per GPU (not peak): defaults to ~45% MFU H100
    /// BF16 ≈ 450 TFLOPs.
    pub flops_per_gpu: f64,
    /// Fraction of communication that overlaps with compute (gradient
    /// bucketing / pipelined collectives).
    pub overlap_fraction: f64,
}

impl Default for GpuComputeConfig {
    fn default() -> Self {
        GpuComputeConfig { flops_per_gpu: 450.0e12, overlap_fraction: 0.6 }
    }
}

impl GpuComputeConfig {
    pub fn a100() -> Self {
        // ~45% MFU of 312 TFLOPs BF16.
        GpuComputeConfig { flops_per_gpu: 140.0e12, overlap_fraction: 0.6 }
    }
}

/// A named experiment preset bundling topology + timing + compute.
#[derive(Debug, Clone)]
pub struct Preset {
    pub name: &'static str,
    pub topo: TopologyConfig,
    pub timing: TimingConfig,
    pub compute: GpuComputeConfig,
    pub checkpoint: CheckpointCostModel,
}

impl Preset {
    /// The paper's 2×(8×H100 + 8×400G IB) physical testbed.
    pub fn testbed() -> Preset {
        Preset {
            name: "testbed-2x8h100",
            topo: TopologyConfig::testbed_h100(),
            timing: TimingConfig::default(),
            compute: GpuComputeConfig::default(),
            checkpoint: CheckpointCostModel::default(),
        }
    }

    /// The paper's SimAI setup at a given server count (8×A100 + 8×200G).
    pub fn simai(n_servers: usize) -> Preset {
        Preset {
            name: "simai-a100",
            topo: TopologyConfig::simai_a100(n_servers),
            timing: TimingConfig::default(),
            compute: GpuComputeConfig::a100(),
            checkpoint: CheckpointCostModel::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_repair_is_low_milliseconds() {
        let t = TimingConfig::default();
        let hr = t.hot_repair_latency();
        assert!(hr > 1.0e-3 && hr < 10.0e-3, "hot repair {hr}s");
    }

    #[test]
    fn lazy_repair_dominated_by_setup() {
        let t = TimingConfig::default();
        assert!(t.lazy_repair_latency() > 8.0 * t.hot_repair_latency());
    }

    #[test]
    fn checkpoint_total_near_68min_plus_lost_work() {
        let c = CheckpointCostModel::default();
        // Paper: median recovery ≈ 68 min of stages; we add lost work.
        let stages = c.detection + c.isolation + c.reload + c.rebuild;
        assert!((stages / 60.0 - 51.0).abs() < 1.0);
        assert!(c.total() > stages);
    }

    #[test]
    fn presets_have_expected_scale() {
        assert_eq!(Preset::testbed().topo.n_servers, 2);
        assert_eq!(Preset::simai(64).topo.n_servers, 64);
        assert!(Preset::simai(4).compute.flops_per_gpu < Preset::testbed().compute.flops_per_gpu);
    }
}
