//! Multi-tier switched fabric: rail-optimised leaf/spine topologies.
//!
//! The flat [`crate::topology::Topology`] models the inter-server network as
//! one non-blocking rail resource per NIC index — the right abstraction for
//! the paper's 2-server testbed, but unable to express the faults that
//! dominate at cluster scale: a leaf (ToR) switch outage takes out the rail
//! connectivity of *every* NIC in its pod at once, a degraded spine or
//! leaf→spine uplink shrinks the capacity of whole path *sets*, and
//! oversubscribed uplinks bottleneck cross-pod collectives (SHIFT,
//! arXiv:2512.11094; observable-CCL, arXiv:2510.00991).
//!
//! This module describes that switched fabric as pure *shape*:
//!
//! * [`FabricConfig`] — `Ideal` (the flat rail model, bit-for-bit identical
//!   to the historical behaviour) or `LeafSpine` (pods of servers, one leaf
//!   switch per (pod, rail), a spine tier every leaf uplinks to, an
//!   oversubscription ratio, and a seeded ECMP spread).
//! * [`Fabric`] — the resolved shape: leaf/spine counts, per-tier
//!   capacities and latencies, NIC↔leaf membership, and the deterministic
//!   ECMP spine pick for a NIC pair.
//! * [`SwitchTarget`] / [`SwitchAction`] / [`SwitchFaultEvent`] — the
//!   switch-scoped fault vocabulary consumed by
//!   [`crate::netsim::FaultPlane`], the executor's switch scripts and the
//!   scenario engine's switch-level patterns.
//!
//! The projection onto engine resources (which resource ids a NIC→NIC hop
//! crosses) lives in [`crate::topology`]: `Topology::build_with_fabric`
//! registers the fabric's resources and `Route::plan` expands the
//! inter-server hop through [`Fabric`]'s path rules.
//!
//! Topology rules (rail-optimised, Spectrum-X style):
//! * Servers are grouped into pods of `pod_size`; pod `p` hosts one leaf
//!   per rail, `leaf = p * nics_per_server + rail`.
//! * NIC `n` (rail `r`, pod `p`) attaches to exactly that leaf.
//! * Same-leaf traffic (same rail, same pod) switches locally:
//!   `NIC → leaf → NIC`.
//! * Everything else crosses the spine:
//!   `NIC → leaf → uplink → spine → uplink → leaf → NIC`, with the spine
//!   chosen by a seeded ECMP hash of the NIC pair (deterministic, so plans
//!   and golden traces are reproducible).
//! * Each leaf has one uplink per spine; the uplink tier's aggregate
//!   capacity is the leaf's downlink capacity divided by the
//!   oversubscription ratio.

use crate::topology::{NicId, TopologyConfig};

/// Leaf switch id: `pod * nics_per_server + rail`.
pub type LeafId = usize;
/// Spine switch id.
pub type SpineId = usize;

/// Leaf/spine shape parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct LeafSpineCfg {
    /// Servers per pod (clamped to the cluster size at build time).
    pub pod_size: usize,
    /// Spine switches; every leaf carries one uplink per spine.
    pub spines: usize,
    /// Downlink/uplink capacity ratio (1.0 = full bisection, 2.0 = 2:1
    /// oversubscribed uplinks). Must be > 0.
    pub oversubscription: f64,
    /// Per-hop switching latency of a leaf or spine traversal.
    pub switch_latency: f64,
    /// Per-hop latency of a leaf↔spine uplink.
    pub uplink_latency: f64,
    /// Seed of the deterministic ECMP spread over parallel uplinks.
    pub ecmp_seed: u64,
}

impl Default for LeafSpineCfg {
    fn default() -> Self {
        LeafSpineCfg {
            pod_size: 8,
            spines: 4,
            oversubscription: 1.0,
            switch_latency: 0.2e-6,
            uplink_latency: 1.0e-6,
            ecmp_seed: 1,
        }
    }
}

/// Which fabric a topology is built over.
#[derive(Debug, Clone, PartialEq)]
pub enum FabricMode {
    /// The flat per-rail model — reproduces the historical behaviour
    /// bit-for-bit (no extra resources, identical paths and latencies).
    Ideal,
    /// Rail-optimised leaf/spine fabric.
    LeafSpine(LeafSpineCfg),
}

/// Fabric selection handed to `Topology::build_with_fabric`.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    pub mode: FabricMode,
}

impl FabricConfig {
    /// The degenerate flat fabric (today's behaviour, bit-for-bit).
    pub fn ideal() -> FabricConfig {
        FabricConfig { mode: FabricMode::Ideal }
    }

    /// A leaf/spine fabric with default shape parameters.
    pub fn leaf_spine() -> FabricConfig {
        FabricConfig { mode: FabricMode::LeafSpine(LeafSpineCfg::default()) }
    }

    /// A leaf/spine fabric with an explicit shape.
    pub fn leaf_spine_with(cfg: LeafSpineCfg) -> FabricConfig {
        FabricConfig { mode: FabricMode::LeafSpine(cfg) }
    }

    /// Parse a CLI-style name: `flat` / `ideal` or `leaf-spine` /
    /// `leaf_spine`.
    pub fn from_name(name: &str) -> Result<FabricConfig, String> {
        match name {
            "flat" | "ideal" => Ok(FabricConfig::ideal()),
            "leaf-spine" | "leaf_spine" => Ok(FabricConfig::leaf_spine()),
            other => Err(format!("unknown fabric {other:?} (expected flat|leaf-spine)")),
        }
    }

    pub fn is_ideal(&self) -> bool {
        matches!(self.mode, FabricMode::Ideal)
    }
}

/// A switch-scoped fault target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchTarget {
    Leaf(LeafId),
    /// Spines support capacity `Degrade` only: NIC-level migration cannot
    /// re-pin ECMP around a *dead* spine, so `note_switch_failure` rejects
    /// `Spine × Down` (scenario patterns never emit it).
    Spine(SpineId),
    /// The uplink between a leaf and a spine (both directions).
    Uplink(LeafId, SpineId),
}

impl SwitchTarget {
    /// Stable serialization label (`leaf:3`, `spine:1`, `uplink:3:1`).
    pub fn label(&self) -> String {
        match self {
            SwitchTarget::Leaf(l) => format!("leaf:{l}"),
            SwitchTarget::Spine(s) => format!("spine:{s}"),
            SwitchTarget::Uplink(l, s) => format!("uplink:{l}:{s}"),
        }
    }

    /// Total order used when sorting compiled switch-event scripts.
    pub fn sort_key(&self) -> (u8, usize, usize) {
        match *self {
            SwitchTarget::Leaf(l) => (0, l, 0),
            SwitchTarget::Spine(s) => (1, s, 0),
            SwitchTarget::Uplink(l, s) => (2, l, s),
        }
    }
}

/// What happens to a switch-scoped element.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SwitchAction {
    /// The element goes dark: every path through it stalls.
    Down,
    /// The element returns at *full* capacity (any standing degradation on
    /// it is cleared).
    Up,
    /// Capacity shrinks to `factor` of nominal (1.0 restores full speed).
    Degrade(f64),
}

impl SwitchAction {
    pub fn label(&self) -> &'static str {
        match self {
            SwitchAction::Down => "down",
            SwitchAction::Up => "up",
            SwitchAction::Degrade(_) => "degrade",
        }
    }

    pub fn factor(&self) -> Option<f64> {
        match self {
            SwitchAction::Degrade(f) => Some(*f),
            _ => None,
        }
    }
}

/// A scripted switch fault, in executor seconds (the switch-scoped sibling
/// of `collectives::exec::FaultEvent`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchFaultEvent {
    pub at: f64,
    pub target: SwitchTarget,
    pub action: SwitchAction,
}

/// The resolved fabric shape of one topology. Pure structure — resource ids
/// live in the owning `Topology`'s table; this type answers membership,
/// capacity and routing questions.
#[derive(Debug, Clone, PartialEq)]
pub struct Fabric {
    mode: FabricMode,
    nics_per_server: usize,
    n_servers: usize,
    /// Servers per pod (leaf radix on the down side); 0 in ideal mode.
    pod_size: usize,
    n_pods: usize,
    n_leaves: usize,
    n_spines: usize,
    /// Leaf down-side (server-facing) capacity in bytes/s per direction.
    pub leaf_cap: f64,
    /// Per-uplink capacity in bytes/s per direction.
    pub uplink_cap: f64,
    /// Spine switching capacity in bytes/s per direction.
    pub spine_cap: f64,
    /// Per-hop leaf/spine switching latency.
    pub switch_latency: f64,
    /// Per-hop uplink latency.
    pub uplink_latency: f64,
    ecmp_seed: u64,
}

impl Fabric {
    /// Resolve a fabric config against a cluster shape.
    pub fn build(topo: &TopologyConfig, cfg: &FabricConfig) -> Fabric {
        match &cfg.mode {
            FabricMode::Ideal => Fabric {
                mode: FabricMode::Ideal,
                nics_per_server: topo.nics_per_server,
                n_servers: topo.n_servers,
                pod_size: 0,
                n_pods: 0,
                n_leaves: 0,
                n_spines: 0,
                leaf_cap: 0.0,
                uplink_cap: 0.0,
                spine_cap: 0.0,
                switch_latency: 0.0,
                uplink_latency: 0.0,
                ecmp_seed: 0,
            },
            FabricMode::LeafSpine(ls) => {
                assert!(ls.pod_size >= 1, "pod_size must be >= 1");
                assert!(ls.spines >= 1, "spines must be >= 1");
                assert!(
                    ls.oversubscription > 0.0 && ls.oversubscription.is_finite(),
                    "oversubscription must be a positive finite ratio"
                );
                let pod_size = ls.pod_size.min(topo.n_servers);
                let n_pods = topo.n_servers.div_ceil(pod_size);
                let n_leaves = n_pods * topo.nics_per_server;
                // Down side is non-blocking: one full-rate port per pod
                // server NIC of the leaf's rail.
                let leaf_cap = topo.nic_bw * pod_size as f64;
                // Aggregate uplink capacity = downlink / oversubscription,
                // spread evenly over one uplink per spine.
                let uplink_cap = leaf_cap / ls.oversubscription / ls.spines as f64;
                // Spines are non-blocking across their attached uplinks.
                let spine_cap = uplink_cap * n_leaves as f64;
                Fabric {
                    mode: FabricMode::LeafSpine(ls.clone()),
                    nics_per_server: topo.nics_per_server,
                    n_servers: topo.n_servers,
                    pod_size,
                    n_pods,
                    n_leaves,
                    n_spines: ls.spines,
                    leaf_cap,
                    uplink_cap,
                    spine_cap,
                    switch_latency: ls.switch_latency,
                    uplink_latency: ls.uplink_latency,
                    ecmp_seed: ls.ecmp_seed,
                }
            }
        }
    }

    pub fn is_ideal(&self) -> bool {
        matches!(self.mode, FabricMode::Ideal)
    }

    pub fn n_pods(&self) -> usize {
        self.n_pods
    }

    pub fn pod_size(&self) -> usize {
        self.pod_size
    }

    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    pub fn n_spines(&self) -> usize {
        self.n_spines
    }

    /// Pod of a server.
    pub fn pod_of_server(&self, server: usize) -> usize {
        debug_assert!(!self.is_ideal());
        server / self.pod_size
    }

    /// Leaf a NIC attaches to.
    pub fn leaf_of_nic(&self, nic: NicId) -> LeafId {
        debug_assert!(!self.is_ideal());
        let server = nic / self.nics_per_server;
        let rail = nic % self.nics_per_server;
        self.pod_of_server(server) * self.nics_per_server + rail
    }

    /// Leaf id of `(pod, rail)`.
    pub fn leaf_id(&self, pod: usize, rail: usize) -> LeafId {
        debug_assert!(pod < self.n_pods && rail < self.nics_per_server);
        pod * self.nics_per_server + rail
    }

    /// The NICs attached to a leaf (rail `leaf % k` of every server in pod
    /// `leaf / k`).
    pub fn nics_of_leaf(&self, leaf: LeafId) -> impl Iterator<Item = NicId> + '_ {
        debug_assert!(!self.is_ideal());
        let pod = leaf / self.nics_per_server;
        let rail = leaf % self.nics_per_server;
        let lo = pod * self.pod_size;
        let hi = ((pod + 1) * self.pod_size).min(self.n_servers);
        (lo..hi).map(move |s| s * self.nics_per_server + rail)
    }

    /// Deterministic ECMP spine pick for a NIC pair: a seeded SplitMix64
    /// finalizer over `(src, dst)` spread uniformly over the spine tier.
    /// Pure in `(src, dst, seed)` — plans, reports and golden traces are
    /// reproducible.
    pub fn ecmp_spine(&self, src: NicId, dst: NicId) -> SpineId {
        debug_assert!(!self.is_ideal());
        let mut z = self
            .ecmp_seed
            .wrapping_add((src as u64) << 32)
            .wrapping_add(dst as u64)
            .wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        (z % self.n_spines as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simai16_cfg() -> TopologyConfig {
        TopologyConfig::simai_a100(16)
    }

    fn leaf_spine4() -> FabricConfig {
        FabricConfig::leaf_spine_with(LeafSpineCfg {
            pod_size: 4,
            spines: 4,
            oversubscription: 2.0,
            ..LeafSpineCfg::default()
        })
    }

    #[test]
    fn ideal_fabric_has_no_switch_tier() {
        let f = Fabric::build(&simai16_cfg(), &FabricConfig::ideal());
        assert!(f.is_ideal());
        assert_eq!(f.n_leaves(), 0);
        assert_eq!(f.n_spines(), 0);
    }

    #[test]
    fn leaf_spine_shape_and_membership() {
        let f = Fabric::build(&simai16_cfg(), &leaf_spine4());
        assert_eq!(f.n_pods(), 4);
        assert_eq!(f.n_leaves(), 4 * 8);
        assert_eq!(f.n_spines(), 4);
        // NIC 0 = server 0, rail 0 → leaf 0; server 5 rail 3 → pod 1.
        assert_eq!(f.leaf_of_nic(0), 0);
        assert_eq!(f.leaf_of_nic(5 * 8 + 3), f.leaf_id(1, 3));
        // Leaf (pod 1, rail 3) hosts rail 3 of servers 4..8.
        let members: Vec<_> = f.nics_of_leaf(f.leaf_id(1, 3)).collect();
        assert_eq!(members, vec![4 * 8 + 3, 5 * 8 + 3, 6 * 8 + 3, 7 * 8 + 3]);
    }

    #[test]
    fn capacities_follow_oversubscription() {
        let topo = simai16_cfg();
        let f = Fabric::build(&topo, &leaf_spine4());
        let down = topo.nic_bw * 4.0;
        assert!((f.leaf_cap - down).abs() < 1e-3);
        // 2:1 oversubscription over 4 spines.
        assert!((f.uplink_cap - down / 2.0 / 4.0).abs() < 1e-3);
        assert!((f.spine_cap - f.uplink_cap * 32.0).abs() < 1e-3);
    }

    #[test]
    fn ecmp_is_deterministic_and_spreads() {
        let f = Fabric::build(&simai16_cfg(), &leaf_spine4());
        let mut seen = [false; 4];
        for src in 0..64 {
            for dst in 64..128 {
                let s = f.ecmp_spine(src, dst);
                assert_eq!(s, f.ecmp_spine(src, dst), "deterministic");
                assert!(s < 4);
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "all spines carry some pair");
    }

    #[test]
    fn ragged_last_pod_is_smaller() {
        let mut topo = simai16_cfg();
        topo.n_servers = 6; // pods of 4 → pod 1 has 2 servers
        let f = Fabric::build(&topo, &leaf_spine4());
        assert_eq!(f.n_pods(), 2);
        let members: Vec<_> = f.nics_of_leaf(f.leaf_id(1, 0)).collect();
        assert_eq!(members, vec![4 * 8, 5 * 8]);
    }

    #[test]
    fn switch_target_labels_are_stable() {
        assert_eq!(SwitchTarget::Leaf(3).label(), "leaf:3");
        assert_eq!(SwitchTarget::Spine(1).label(), "spine:1");
        assert_eq!(SwitchTarget::Uplink(3, 1).label(), "uplink:3:1");
        assert_eq!(SwitchAction::Degrade(0.5).label(), "degrade");
        assert_eq!(SwitchAction::Degrade(0.5).factor(), Some(0.5));
        assert_eq!(SwitchAction::Down.factor(), None);
    }
}
