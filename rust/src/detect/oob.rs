//! The out-of-band bootstrap network (§4.1).
//!
//! A lightweight TCP/MPI network over a non-datapath NIC. R²CCL uses it for
//! bilateral failure notification (peer alerts) and for broadcasting a
//! confirmed diagnosis to all ranks. We model it as latency constants plus
//! a delivered-message log, so tests can assert both timing and "nobody is
//! left waiting on a dead connection".

use crate::config::TimingConfig;

/// A message on the bootstrap network.
#[derive(Debug, Clone, PartialEq)]
pub enum OobMessage {
    /// "I observed an error on our connection" — sent to the peer rank.
    ErrorAlert { from_rank: usize, to_rank: usize },
    /// Confirmed diagnosis broadcast to every rank.
    DiagnosisBroadcast { origin_rank: usize, detail: String },
}

/// Delivery record: (deliver_at, destination_rank, message).
pub type Delivery = (f64, usize, OobMessage);

/// The OOB network: computes delivery times and logs traffic.
#[derive(Debug, Clone)]
pub struct OobNetwork {
    n_ranks: usize,
    notify_latency: f64,
    broadcast_latency: f64,
    pub log: Vec<Delivery>,
}

impl OobNetwork {
    pub fn new(n_ranks: usize, timing: &TimingConfig) -> Self {
        OobNetwork {
            n_ranks,
            notify_latency: timing.oob_notify,
            broadcast_latency: timing.oob_broadcast,
            log: Vec::new(),
        }
    }

    /// Bilateral alert: rank `from` tells rank `to` the connection is dead.
    /// Returns the delivery time.
    pub fn notify_peer(&mut self, now: f64, from: usize, to: usize) -> f64 {
        assert!(from < self.n_ranks && to < self.n_ranks);
        let at = now + self.notify_latency;
        self.log.push((at, to, OobMessage::ErrorAlert { from_rank: from, to_rank: to }));
        at
    }

    /// Broadcast a diagnosis to all ranks; returns the time the last rank
    /// has it (a small bootstrap tree, modelled as one constant).
    pub fn broadcast_diagnosis(&mut self, now: f64, origin: usize, detail: &str) -> f64 {
        let at = now + self.broadcast_latency;
        for r in 0..self.n_ranks {
            if r != origin {
                self.log.push((
                    at,
                    r,
                    OobMessage::DiagnosisBroadcast { origin_rank: origin, detail: detail.to_string() },
                ));
            }
        }
        at
    }

    /// Ranks that have been alerted about a failure by time `t`.
    pub fn alerted_ranks(&self, t: f64) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .log
            .iter()
            .filter(|(at, _, m)| *at <= t && matches!(m, OobMessage::ErrorAlert { .. }))
            .map(|(_, to, _)| *to)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oob() -> OobNetwork {
        OobNetwork::new(16, &TimingConfig::default())
    }

    #[test]
    fn peer_notification_is_milliseconds() {
        let mut n = oob();
        let at = n.notify_peer(1.0, 3, 7);
        assert!(at - 1.0 < 1.0e-3, "notify took {}", at - 1.0);
        assert_eq!(n.alerted_ranks(at), vec![7]);
        assert!(n.alerted_ranks(at - 1e-6).is_empty());
    }

    #[test]
    fn broadcast_reaches_everyone_but_origin() {
        let mut n = oob();
        let at = n.broadcast_diagnosis(0.0, 5, "nic 2 down");
        let recipients: Vec<usize> = n
            .log
            .iter()
            .filter(|(t, _, m)| *t <= at && matches!(m, OobMessage::DiagnosisBroadcast { .. }))
            .map(|(_, to, _)| *to)
            .collect();
        assert_eq!(recipients.len(), 15);
        assert!(!recipients.contains(&5));
    }

    #[test]
    fn bilateral_no_half_open() {
        // Both endpoints alert each other; both sides know within the OOB
        // budget — the "half-open" state of §4.1 cannot persist.
        let mut n = oob();
        let a = n.notify_peer(0.0, 0, 8);
        let b = n.notify_peer(0.0, 8, 0);
        assert_eq!(n.alerted_ranks(a.max(b)), vec![0, 8]);
    }
}
