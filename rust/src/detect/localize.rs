//! Online gray-fault localization (SHIFT-style skew attribution).
//!
//! Crisp faults are easy: the NIC throws an error CQE and [`triangulate`]
//! names the culprit in one round. Gray faults never announce themselves —
//! a silently lossy uplink or a straggling NIC only shows up as *skew* in
//! the per-collective telemetry: some NIC pairs retransmit more than their
//! peers, some probe RTTs run long. The localizer turns one telemetry
//! window into a ranked list of suspect elements by walking the skew down
//! the topology tiers:
//!
//! 1. every sample (a pair's retransmit rate, a probe's RTT) is z-scored
//!    against its own signal family, so families with different units pool;
//! 2. every fabric element a sample's path crosses (endpoint NICs, leaves,
//!    the ECMP-pinned spine, uplink halves — the same walk as
//!    `FaultPlane::path_gray`) is a candidate;
//! 3. a candidate's score is the mean z of samples *crossing* it minus the
//!    mean z of samples that avoid it — an element is suspicious exactly
//!    when the traffic through it is elevated *and* the traffic around it
//!    is not. Dilution does the tier separation: a gray uplink's crossing
//!    set covers all elevated samples while each endpoint NIC's covers
//!    only a slice, and vice versa for a gray NIC.
//!
//! The function is pure (no RNG, no fault-plane access — it sees only what
//! real telemetry would carry), so the scenario runner can score it against
//! the ground-truth gray script it compiled.
//!
//! [`triangulate`]: crate::detect::triangulate

use std::collections::BTreeMap;

use crate::netsim::GrayTarget;
use crate::topology::{NicId, Topology};

/// Aggregated data-path telemetry for one (src NIC, dst NIC) pair over a
/// telemetry window: how much the pair moved and how much of it the wire
/// made them resend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairSample {
    pub src_nic: NicId,
    pub dst_nic: NicId,
    /// Goodput bytes delivered between the pair.
    pub bytes: u64,
    /// Seconds the pair had flows in flight (busy time).
    pub busy: f64,
    /// Extra wire bytes spent on retransmits.
    pub retrans: u64,
}

impl PairSample {
    /// Fraction of wire bytes that were retransmits, in `[0, 1)`.
    pub fn retrans_rate(&self) -> f64 {
        let total = self.bytes + self.retrans;
        if total == 0 {
            0.0
        } else {
            self.retrans as f64 / total as f64
        }
    }
}

/// One timed probe observation between two NICs (see
/// [`timed_probe`](crate::detect::timed_probe)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RttSample {
    pub from: NicId,
    pub to: NicId,
    /// Measured round-trip time in seconds.
    pub rtt: f64,
}

/// A telemetry window: everything the localizer is allowed to see.
#[derive(Debug, Clone, Copy, Default)]
pub struct LocalizeWindow<'a> {
    pub pairs: &'a [PairSample],
    pub rtts: &'a [RttSample],
}

/// A ranked suspect: a fabric element and its attribution score (higher =
/// more suspicious; healthy elements sit near zero or below).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Suspect {
    pub target: GrayTarget,
    pub score: f64,
}

/// The fabric elements a (from, to) path crosses — the candidate set and
/// crossing relation. Mirrors `FaultPlane::path_gray`'s walk exactly so a
/// gray element is always a candidate for the samples it taints.
fn path_elements(topo: &Topology, from: NicId, to: NicId, out: &mut Vec<GrayTarget>) {
    use crate::fabric::SwitchTarget;
    out.clear();
    out.push(GrayTarget::Nic(from));
    if to != from {
        out.push(GrayTarget::Nic(to));
    }
    let nps = topo.cfg.nics_per_server;
    let fabric = topo.fabric();
    if from / nps != to / nps && !fabric.is_ideal() {
        let lf = fabric.leaf_of_nic(from);
        let lt = fabric.leaf_of_nic(to);
        out.push(GrayTarget::Switch(SwitchTarget::Leaf(lf)));
        if lt != lf {
            out.push(GrayTarget::Switch(SwitchTarget::Leaf(lt)));
            let s = fabric.ecmp_spine(from, to);
            out.push(GrayTarget::Switch(SwitchTarget::Spine(s)));
            out.push(GrayTarget::Switch(SwitchTarget::Uplink(lf, s)));
            out.push(GrayTarget::Switch(SwitchTarget::Uplink(lt, s)));
        }
    }
}

/// Per-candidate accumulator: z-mass of samples crossing the element, per
/// signal family, plus the crossing count.
#[derive(Default, Clone, Copy)]
struct Tally {
    z_sum: [f64; 2],
    n: [usize; 2],
}

const FAMILY_RETRANS: usize = 0;
const FAMILY_RTT: usize = 1;

/// Z-score a signal family in place; returns `None` (family carries no
/// attribution signal) when it is empty or has no variance.
fn zscores(xs: &[f64]) -> Option<Vec<f64>> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let sd = var.sqrt();
    if !(sd > 1e-15) {
        return None;
    }
    Some(xs.iter().map(|x| (x - mean) / sd).collect())
}

/// Rank fabric elements by how strongly the telemetry window implicates
/// them. Returns suspects sorted by descending score (ties broken by the
/// element's total order, so the ranking is deterministic). An empty or
/// perfectly uniform window returns an empty ranking — nothing to blame.
pub fn localize(topo: &Topology, window: &LocalizeWindow) -> Vec<Suspect> {
    // Family z-scores. A family that is empty or flat contributes nothing.
    let pair_z = zscores(&window.pairs.iter().map(|p| p.retrans_rate()).collect::<Vec<_>>());
    let rtt_z = zscores(&window.rtts.iter().map(|r| r.rtt).collect::<Vec<_>>());
    if pair_z.is_none() && rtt_z.is_none() {
        return Vec::new();
    }

    // Accumulate crossing z-mass per candidate element.
    let mut tallies: BTreeMap<(u8, usize, usize), (GrayTarget, Tally)> = BTreeMap::new();
    let mut path = Vec::with_capacity(8);
    let mut family_n = [0usize; 2];
    let mut family_total = [0.0f64; 2];
    let mut fold = |family: usize,
                    z: f64,
                    elems: &[GrayTarget],
                    tallies: &mut BTreeMap<(u8, usize, usize), (GrayTarget, Tally)>| {
        family_n[family] += 1;
        family_total[family] += z;
        for &t in elems {
            let e = tallies.entry(t.sort_key()).or_insert((t, Tally::default()));
            e.1.z_sum[family] += z;
            e.1.n[family] += 1;
        }
    };
    if let Some(zs) = &pair_z {
        for (p, &z) in window.pairs.iter().zip(zs) {
            path_elements(topo, p.src_nic, p.dst_nic, &mut path);
            fold(FAMILY_RETRANS, z, &path, &mut tallies);
        }
    }
    if let Some(zs) = &rtt_z {
        for (r, &z) in window.rtts.iter().zip(zs) {
            path_elements(topo, r.from, r.to, &mut path);
            fold(FAMILY_RTT, z, &path, &mut tallies);
        }
    }

    // Score: mean z of crossing samples minus mean z of the rest, summed
    // over the families the element appears in. An element every sample
    // crosses cannot be separated from the baseline and scores 0 for that
    // family.
    let mut suspects: Vec<Suspect> = tallies
        .into_values()
        .map(|(target, t)| {
            let mut score = 0.0;
            for f in 0..2 {
                let n_in = t.n[f];
                let n_out = family_n[f] - n_in;
                if n_in == 0 || n_out == 0 {
                    continue;
                }
                let mean_in = t.z_sum[f] / n_in as f64;
                let mean_out = (family_total[f] - t.z_sum[f]) / n_out as f64;
                score += mean_in - mean_out;
            }
            Suspect { target, score }
        })
        .collect();
    suspects.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.target.sort_key().cmp(&b.target.sort_key()))
    });
    suspects
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::SwitchTarget;
    use crate::topology::{Topology, TopologyConfig};

    fn flat_topo() -> Topology {
        let mut cfg = TopologyConfig::testbed_h100();
        cfg.n_servers = 4;
        Topology::build(&cfg)
    }

    fn pair(src: usize, dst: usize, retrans: u64) -> PairSample {
        PairSample { src_nic: src, dst_nic: dst, bytes: 1_000_000, busy: 1.0e-3, retrans }
    }

    #[test]
    fn empty_window_blames_nobody() {
        let t = flat_topo();
        assert!(localize(&t, &LocalizeWindow::default()).is_empty());
        // Uniform telemetry (no variance) likewise.
        let pairs = [pair(0, 8, 0), pair(8, 16, 0), pair(16, 24, 0)];
        let w = LocalizeWindow { pairs: &pairs, rtts: &[] };
        assert!(localize(&t, &w).is_empty());
    }

    #[test]
    fn lossy_nic_tops_the_ranking_on_flat_fabric() {
        let t = flat_topo();
        // NIC 8 silently drops: every pair touching it retransmits, the
        // rest are clean. Probes from third vantages break the endpoint
        // tie (pairs alone cannot tell NIC 8 from its constant peers).
        let pairs = [pair(0, 8, 50_000), pair(8, 16, 50_000), pair(16, 24, 0), pair(24, 0, 0)];
        let rtts = [
            RttSample { from: 16, to: 8, rtt: 4.0e-5 },
            RttSample { from: 24, to: 8, rtt: 4.0e-5 },
            RttSample { from: 16, to: 0, rtt: 1.0e-5 },
            RttSample { from: 24, to: 16, rtt: 1.0e-5 },
        ];
        let w = LocalizeWindow { pairs: &pairs, rtts: &rtts };
        let ranked = localize(&t, &w);
        assert_eq!(ranked[0].target, GrayTarget::Nic(8), "ranking: {ranked:?}");
        assert!(ranked[0].score > 0.0);
    }

    #[test]
    fn shared_uplink_outranks_its_endpoint_nics() {
        // Leaf/spine fabric: many distinct NIC pairs all crossing one
        // uplink retransmit. No single NIC explains all of them — the
        // uplink's crossing set does, so dilution pushes it to the top.
        use crate::fabric::{FabricConfig, LeafSpineCfg};
        let mut cfg = TopologyConfig::testbed_h100();
        cfg.n_servers = 16;
        let t = Topology::build_with_fabric(
            &cfg,
            &FabricConfig::leaf_spine_with(LeafSpineCfg {
                pod_size: 4,
                spines: 2,
                ..LeafSpineCfg::default()
            }),
        );
        let fabric = t.fabric();
        assert!(!fabric.is_ideal());
        // Rail-0 NICs of servers 0..8 vs 8..16: always cross-leaf. Bucket
        // pairs so every confounder of the gray uplink (lf0, sp0) gets
        // clean dilution traffic: the source leaf alone (other spine), the
        // spine alone (other leaf), and fully disjoint pairs.
        let (mut tainted, mut clean) = (Vec::new(), Vec::new());
        let (mut lf0, mut sp0) = (usize::MAX, usize::MAX);
        for src in (0..8).map(|s| s * 8) {
            for dst in (8..16).map(|s| s * 8) {
                let lf = fabric.leaf_of_nic(src);
                assert_ne!(lf, fabric.leaf_of_nic(dst));
                let s = fabric.ecmp_spine(src, dst);
                if lf0 == usize::MAX {
                    (lf0, sp0) = (lf, s);
                }
                let on_uplink = lf == lf0 && s == sp0;
                if on_uplink && tainted.len() < 6 {
                    tainted.push(pair(src, dst, 80_000));
                } else if !on_uplink {
                    clean.push(pair(src, dst, 0));
                }
            }
        }
        assert!(tainted.len() >= 3, "need several pairs over one uplink");
        assert!(clean.iter().any(|p| {
            fabric.leaf_of_nic(p.src_nic) == lf0
                && fabric.ecmp_spine(p.src_nic, p.dst_nic) != sp0
        }));
        assert!(clean.iter().any(|p| {
            fabric.leaf_of_nic(p.src_nic) != lf0
                && fabric.ecmp_spine(p.src_nic, p.dst_nic) == sp0
        }));
        let pairs: Vec<_> = tainted.iter().chain(&clean).copied().collect();
        let w = LocalizeWindow { pairs: &pairs, rtts: &[] };
        let ranked = localize(&t, &w);
        assert_eq!(
            ranked[0].target,
            GrayTarget::Switch(SwitchTarget::Uplink(lf0, sp0)),
            "ranking: {ranked:?}"
        );
    }

    #[test]
    fn ranking_is_deterministic() {
        let t = flat_topo();
        let pairs = [pair(0, 8, 10_000), pair(8, 16, 10_000), pair(16, 24, 0)];
        let w = LocalizeWindow { pairs: &pairs, rtts: &[] };
        let a = localize(&t, &w);
        let b = localize(&t, &w);
        assert_eq!(a, b);
    }
}
