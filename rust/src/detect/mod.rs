//! Failure detection and localization (§4.1–§4.2).
//!
//! * Bilateral error awareness: when either endpoint sees an error it
//!   notifies its peer over the out-of-band bootstrap network, so nobody
//!   spins on a dead connection (detection drops from minutes to
//!   milliseconds).
//! * Precise localization: dedicated probe QP pools issue zero-byte RDMA
//!   writes from both endpoints plus an auxiliary NIC; correlating the
//!   outcomes (local error vs timeout) separates "my NIC died", "their NIC
//!   died" and "the cable died".
//! * Periodic reprobing detects component recovery (NIC resets, cable
//!   fixes) so repaired links rejoin the pool.
//! * Gray-fault localization ([`localize`]): crisp faults announce
//!   themselves through error CQEs, gray ones only skew telemetry — the
//!   localizer turns a per-collective telemetry window into a ranked list
//!   of suspect elements, SHIFT-style.

pub mod localize;
pub mod oob;
pub mod probe;

pub use localize::{localize, LocalizeWindow, PairSample, RttSample, Suspect};
pub use oob::OobNetwork;
pub use probe::{
    pick_aux_nic, reprobe_recovered, timed_probe, triangulate, Diagnosis, ProbeReport, TimedProbe,
};
