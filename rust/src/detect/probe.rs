//! Probe-based fault localization (§4.2 three-point triangulation).
//!
//! RDMA exposes only coarse transport errors (retry-exceeded) that do not
//! say *which* endpoint failed. R²CCL keeps dedicated probe QP pools,
//! isolated from the data path, and on error issues zero-byte RDMA writes
//! from three vantage points: the local NIC, the peer NIC, and an auxiliary
//! NIC on a third node. The outcome pattern identifies the fault:
//!
//! | local probe | peer probe | aux → local | aux → peer | diagnosis |
//! |---|---|---|---|---|
//! | LocalError  | Timeout    | Timeout     | Ok         | local NIC fault |
//! | Timeout     | LocalError | Ok          | Timeout    | remote NIC fault |
//! | Timeout     | Timeout    | Ok/Timeout  | Ok/Timeout | link (cable) fault |

use crate::config::TimingConfig;
use crate::netsim::{FaultPlane, ProbeOutcome};
use crate::topology::{NicId, Topology};

/// Where the fault is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Diagnosis {
    /// The NIC at the rank that ran the triangulation.
    LocalNicFault,
    /// The peer's NIC.
    RemoteNicFault,
    /// The cable / link between them (both NICs fine).
    LinkFault,
    /// Probes came back clean — transient error (e.g. QP-level); retry on
    /// the same path after re-establishing the QP.
    Transient,
}

/// The full probe evidence plus timing.
#[derive(Debug, Clone)]
pub struct ProbeReport {
    pub diagnosis: Diagnosis,
    /// Wall-clock cost of the triangulation (parallel probes: the max of
    /// the individual probe costs).
    pub elapsed: f64,
    pub local_probe: ProbeOutcome,
    pub peer_probe: ProbeOutcome,
    pub aux_to_local: ProbeOutcome,
    pub aux_to_peer: ProbeOutcome,
}

fn probe_cost(timing: &TimingConfig, o: ProbeOutcome) -> f64 {
    match o {
        ProbeOutcome::Ok => timing.probe_rtt,
        // An error CQE surfaces immediately (local NIC rejects the WR).
        ProbeOutcome::LocalError => timing.probe_rtt,
        ProbeOutcome::Timeout => timing.probe_timeout,
    }
}

/// Run three-point triangulation for a failed connection between
/// `local_nic` and `peer_nic`, using `aux_nic` (a NIC on a third node, or a
/// second healthy NIC pair when the cluster has only two nodes).
pub fn triangulate(
    topo: &Topology,
    timing: &TimingConfig,
    faults: &FaultPlane,
    local_nic: NicId,
    peer_nic: NicId,
    aux_nic: NicId,
) -> ProbeReport {
    debug_assert_ne!(topo.server_of_nic(local_nic), topo.server_of_nic(peer_nic));
    let local_probe = faults.probe(local_nic, peer_nic);
    let peer_probe = faults.probe(peer_nic, local_nic);
    let aux_to_local = faults.probe(aux_nic, local_nic);
    let aux_to_peer = faults.probe(aux_nic, peer_nic);

    let diagnosis = match (local_probe, peer_probe) {
        (ProbeOutcome::LocalError, _) => Diagnosis::LocalNicFault,
        (_, ProbeOutcome::LocalError) => Diagnosis::RemoteNicFault,
        (ProbeOutcome::Timeout, ProbeOutcome::Timeout) => {
            // Both time out: NIC-hardware faults also time out from the
            // remote side, so use the auxiliary vantage to separate
            // single-endpoint impairment from a dead link.
            match (aux_to_local, aux_to_peer) {
                (ProbeOutcome::Timeout, ProbeOutcome::Ok) => Diagnosis::LocalNicFault,
                (ProbeOutcome::Ok, ProbeOutcome::Timeout) => Diagnosis::RemoteNicFault,
                _ => Diagnosis::LinkFault,
            }
        }
        // One side ok, other timeout without local error: degraded path —
        // treat as link fault (conservative: migrate off it).
        (ProbeOutcome::Timeout, ProbeOutcome::Ok) | (ProbeOutcome::Ok, ProbeOutcome::Timeout) => {
            Diagnosis::LinkFault
        }
        (ProbeOutcome::Ok, ProbeOutcome::Ok) => Diagnosis::Transient,
    };

    // All probes are issued in parallel from their owners; evidence is
    // correlated at the local rank after OOB exchange of outcomes.
    let elapsed = [
        probe_cost(timing, local_probe),
        probe_cost(timing, peer_probe),
        probe_cost(timing, aux_to_local),
        probe_cost(timing, aux_to_peer),
    ]
    .into_iter()
    .fold(0.0_f64, f64::max)
        + timing.oob_notify; // outcome exchange

    ProbeReport { diagnosis, elapsed, local_probe, peer_probe, aux_to_local, aux_to_peer }
}

/// A probe outcome plus the round-trip latency sample it measured.
///
/// [`triangulate`] only needs the outcome pattern (which endpoint answered),
/// so its cost model is the coarse `probe_rtt`/`probe_timeout` pair and is
/// deliberately left untouched — detection latency feeds completion times
/// and therefore the golden traces. Telemetry wants more: a probe over a
/// degraded or gray path comes back *late*, and that lateness is exactly
/// the signal the localizer ranks on. `timed_probe` models it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedProbe {
    pub outcome: ProbeOutcome,
    /// Measured round-trip time in seconds. `probe_rtt` on a clean path,
    /// inflated by crisp degradation (capacity factor) and gray state
    /// (straggler slowdown, loss-driven retransmits, expected jitter) on an
    /// impaired one, and pinned to `probe_timeout` when the probe dies.
    pub rtt: f64,
}

/// Issue one telemetry probe from `from` to `to` and time it.
///
/// The RTT model is deterministic (no RNG — jitter enters as its expected
/// value, the summed jitter amplitude along the path):
///
/// * outcome `Timeout` → `probe_timeout`; `LocalError` → `probe_rtt` (the
///   error CQE surfaces immediately, nothing crossed the wire);
/// * outcome `Ok` → `probe_rtt` stretched by the slowest endpoint's crisp
///   `capacity_factor`, then by the path's composed gray state:
///   `straggler_factor / (1 - loss_rate)` (a straggler serializes the
///   zero-byte write's doorbell/CQE handling; loss forces retransmits even
///   on tiny messages), plus `probe_rtt · latency_jitter` of expected
///   jitter.
pub fn timed_probe(
    timing: &TimingConfig,
    faults: &FaultPlane,
    from: NicId,
    to: NicId,
) -> TimedProbe {
    let outcome = faults.probe(from, to);
    let rtt = match outcome {
        ProbeOutcome::Timeout => timing.probe_timeout,
        ProbeOutcome::LocalError => timing.probe_rtt,
        ProbeOutcome::Ok => {
            let crisp = faults
                .capacity_factor(from)
                .min(faults.capacity_factor(to))
                .max(crate::netsim::MIN_DEGRADE_FACTOR);
            let g = faults.path_gray(from, to);
            let gray_stretch = g.straggler_factor / (1.0 - g.loss_rate);
            let base = timing.probe_rtt / crisp * gray_stretch;
            // Expected jitter contribution: amplitude × nominal RTT.
            (base + timing.probe_rtt * g.latency_jitter).min(timing.probe_timeout)
        }
    };
    TimedProbe { outcome, rtt }
}

/// Pick an auxiliary NIC for triangulation: prefer a NIC on a third server;
/// in a two-server cluster use another healthy NIC pair on the same servers
/// (the probe still distinguishes endpoint vs link for the *failed* pair).
pub fn pick_aux_nic(
    topo: &Topology,
    faults: &FaultPlane,
    local_nic: NicId,
    peer_nic: NicId,
) -> Option<NicId> {
    let s_local = topo.server_of_nic(local_nic);
    let s_peer = topo.server_of_nic(peer_nic);
    // Third server first.
    for s in 0..topo.n_servers() {
        if s != s_local && s != s_peer {
            if let Some(n) = faults.healthy_nics(topo, s).first() {
                return Some(*n);
            }
        }
    }
    // Fallback: a different healthy NIC on the peer's server.
    faults
        .healthy_nics(topo, s_peer)
        .into_iter()
        .find(|&n| n != peer_nic)
        .or_else(|| {
            faults
                .healthy_nics(topo, s_local)
                .into_iter()
                .find(|&n| n != local_nic)
        })
}

/// Periodic reprobe: true if the previously-failed NIC pair answers again
/// (component recovered, e.g. NIC reset or cable replaced — §4.2).
pub fn reprobe_recovered(faults: &FaultPlane, local_nic: NicId, peer_nic: NicId) -> bool {
    faults.probe(local_nic, peer_nic) == ProbeOutcome::Ok
        && faults.probe(peer_nic, local_nic) == ProbeOutcome::Ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    fn setup3() -> (Topology, crate::netsim::Engine, FaultPlane, TimingConfig) {
        // Three servers so a true third-party aux NIC exists.
        let mut cfg = TopologyConfig::testbed_h100();
        cfg.n_servers = 3;
        let t = Topology::build(&cfg);
        let eng = netsim::engine_for(&t);
        let fp = FaultPlane::new(&t);
        (t, eng, fp, TimingConfig::default())
    }

    #[test]
    fn local_nic_fault_is_localized() {
        let (t, mut eng, mut fp, tm) = setup3();
        fp.fail_nic(&t, &mut eng, 0);
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        assert_eq!(t.server_of_nic(aux), 2);
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        assert_eq!(r.diagnosis, Diagnosis::LocalNicFault);
        assert!(r.elapsed <= tm.probe_timeout + tm.oob_notify);
    }

    #[test]
    fn remote_nic_fault_is_localized() {
        let (t, mut eng, mut fp, tm) = setup3();
        fp.fail_nic(&t, &mut eng, 8);
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        assert_eq!(r.diagnosis, Diagnosis::RemoteNicFault);
    }

    #[test]
    fn cable_fault_is_localized() {
        let (t, mut eng, mut fp, tm) = setup3();
        fp.cut_cable(&t, &mut eng, 0);
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        // Cable on the local side: local probe times out, peer probe times
        // out, aux→local times out, aux→peer ok → classified as local-side
        // impairment per the truth table.
        assert_eq!(r.diagnosis, Diagnosis::LocalNicFault);
    }

    #[test]
    fn transient_error_probes_clean() {
        let (t, _eng, fp, tm) = setup3();
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        assert_eq!(r.diagnosis, Diagnosis::Transient);
        // Healthy probes finish in microseconds.
        assert!(r.elapsed < 1.0e-3);
    }

    #[test]
    fn two_server_cluster_uses_fallback_aux() {
        let t = Topology::build(&TopologyConfig::testbed_h100());
        let mut eng = netsim::engine_for(&t);
        let mut fp = FaultPlane::new(&t);
        fp.fail_nic(&t, &mut eng, 0);
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        // Aux on server 1 (peer's server) but a different NIC.
        assert_eq!(t.server_of_nic(aux), 1);
        assert_ne!(aux, 8);
        let tm = TimingConfig::default();
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        assert_eq!(r.diagnosis, Diagnosis::LocalNicFault);
    }

    #[test]
    fn reprobe_detects_recovery() {
        let (t, mut eng, mut fp, _tm) = setup3();
        fp.fail_nic(&t, &mut eng, 0);
        assert!(!reprobe_recovered(&fp, 0, 8));
        fp.repair(&t, &mut eng, 0);
        assert!(reprobe_recovered(&fp, 0, 8));
    }

    #[test]
    fn timed_probe_healthy_is_nominal_rtt() {
        let (_t, _eng, fp, tm) = setup3();
        let p = timed_probe(&tm, &fp, 0, 8);
        assert_eq!(p.outcome, ProbeOutcome::Ok);
        assert_eq!(p.rtt, tm.probe_rtt);
    }

    #[test]
    fn timed_probe_stretches_with_crisp_degradation() {
        let (t, mut eng, mut fp, tm) = setup3();
        fp.set_state(&t, &mut eng, 8, crate::netsim::NicState::Degraded(0.25));
        let p = timed_probe(&tm, &fp, 0, 8);
        assert_eq!(p.outcome, ProbeOutcome::Ok);
        // Slowest endpoint at 25% capacity → 4× the nominal RTT.
        assert!((p.rtt - tm.probe_rtt / 0.25).abs() < 1e-12, "rtt {}", p.rtt);
    }

    #[test]
    fn timed_probe_sees_gray_loss_straggle_and_jitter() {
        use crate::netsim::{GrayState, GrayTarget};
        let (t, mut eng, mut fp, tm) = setup3();
        fp.set_gray(
            &t,
            &mut eng,
            GrayTarget::Nic(8),
            GrayState { loss_rate: 0.2, latency_jitter: 0.5, straggler_factor: 2.0 },
        );
        let p = timed_probe(&tm, &fp, 0, 8);
        assert_eq!(p.outcome, ProbeOutcome::Ok);
        // 2× straggler / (1 − 0.2) loss + 0.5 expected jitter = 3× nominal.
        let want = tm.probe_rtt * (2.0 / 0.8) + tm.probe_rtt * 0.5;
        assert!((p.rtt - want).abs() < 1e-12, "rtt {} want {}", p.rtt, want);
        // Gray never flips the probe outcome — that is the whole point of a
        // gray fault: the crisp oracle still says everything is fine.
        assert!(p.rtt < tm.probe_timeout);
    }

    #[test]
    fn timed_probe_pins_failures_to_coarse_costs() {
        let (t, mut eng, mut fp, tm) = setup3();
        fp.fail_nic(&t, &mut eng, 0);
        let local = timed_probe(&tm, &fp, 0, 8);
        assert_eq!(local.outcome, ProbeOutcome::LocalError);
        assert_eq!(local.rtt, tm.probe_rtt);
        let toward = timed_probe(&tm, &fp, 8, 0);
        assert_eq!(toward.outcome, ProbeOutcome::Timeout);
        assert_eq!(toward.rtt, tm.probe_timeout);
    }

    #[test]
    fn detection_is_milliseconds_not_minutes() {
        // End-to-end detection budget = CQ error + OOB notify + probes:
        // the §4.1 claim ("minutes to milliseconds").
        let (t, mut eng, mut fp, tm) = setup3();
        fp.fail_nic(&t, &mut eng, 0);
        let aux = pick_aux_nic(&t, &fp, 0, 8).unwrap();
        let r = triangulate(&t, &tm, &fp, 0, 8, aux);
        let total = tm.cq_error_delay + tm.oob_notify + r.elapsed;
        assert!(total < 10.0e-3, "detection path {total}s");
    }
}
