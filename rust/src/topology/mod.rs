//! Static cluster topology: servers, GPUs, NICs, NVLink, PCIe, NUMA, rails.
//!
//! This is the substrate the paper's testbed provides in hardware (2 servers
//! of 8×H100 + 8×CX-7, rail-optimised fabric) and SimAI provides in
//! simulation (up to 128 servers of 8×A100 + 8×200G NICs). We model it as a
//! resource graph: every shareable capacity (a NIC direction, a GPU's NVLink
//! aggregate, a PCIe lane, a NUMA interconnect, a rail's ToR) is one
//! *resource* with a capacity in bytes/s. Transfers are flows over resource
//! paths; the fluid-flow engine in [`crate::netsim`] shares capacities
//! max-min fair.
//!
//! Conventions
//! * GPUs and NICs are numbered globally; server `s` owns GPUs
//!   `s*g .. (s+1)*g` and NICs `s*k .. (s+1)*k`.
//! * GPU local index `i` has *affinity* NIC local index `i % nics_per_server`
//!   (the paper's 1:1 GPU↔NIC PCIe pairing).
//! * NIC local index `i` belongs to *rail* `i`: rail-optimised fabrics
//!   connect NIC `i` of every server to leaf switch `i`.
//! * NUMA: the first half of GPUs/NICs of a server sit on socket 0, the
//!   second half on socket 1 (matching DGX/HGX layouts).

pub mod path;
pub mod rankset;

use std::collections::HashMap;
use std::sync::Arc;

use crate::fabric::{Fabric, FabricConfig, LeafId, SpineId};

pub use path::{Route, RoutePlan};
pub use rankset::RankSet;

/// Global GPU id.
pub type GpuId = usize;
/// Global NIC id.
pub type NicId = usize;
/// Server id.
pub type ServerId = usize;
/// Rail index (NIC local index; rail-optimised fabric).
pub type RailId = usize;
/// Dense resource id used by the netsim engine.
pub type ResourceId = usize;

/// What a resource physically is. Tx/Rx are separate resources because the
/// links are full duplex (a ring AllReduce sends and receives at line rate
/// simultaneously on every NIC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceKey {
    /// NIC egress (server → fabric).
    NicTx(NicId),
    /// NIC ingress.
    NicRx(NicId),
    /// GPU's aggregate NVLink egress bandwidth.
    NvlTx(GpuId),
    /// GPU's aggregate NVLink ingress bandwidth.
    NvlRx(GpuId),
    /// PCIe lane between the GPU/PCIe-switch complex and one NIC, up
    /// direction (towards NIC).
    PcieUp(NicId),
    /// Same lane, down direction.
    PcieDown(NicId),
    /// Cross-socket interconnect (UPI/QPI) of one server, one direction
    /// (0 = socket0→socket1, 1 = reverse).
    Upi(ServerId, u8),
    /// Rail leaf switch capacity (effectively non-blocking unless a
    /// switch-outage scenario degrades it). The *flat* fabric's only
    /// inter-server resource; leaf/spine fabrics use the switch-tier keys
    /// below instead.
    TorRail(RailId),
    /// Leaf switch ingress (server → fabric) port pool of one leaf
    /// (leaf/spine fabrics only).
    LeafIn(LeafId),
    /// Leaf switch egress (fabric → server) port pool.
    LeafOut(LeafId),
    /// Spine switch switching capacity, one direction-less pool per spine.
    SpineSw(SpineId),
    /// Leaf→spine uplink (up direction) between a leaf and a spine.
    UplinkTx(LeafId, SpineId),
    /// Spine→leaf downlink (down direction) of the same physical link.
    UplinkRx(LeafId, SpineId),
}

/// Hierarchical rate-aggregation domains: a partition of the resource
/// table keyed on fabric tiers. The netsim engine scopes every rate
/// recompute to the dirty-domain closure, so a change local to one pod
/// never touches remote pods' resources.
///
/// Partition layout:
/// * flat (ideal) fabric — one domain per server (all its NICs, PCIe
///   lanes, NVLink pools, UPI links) plus one domain per rail ToR;
/// * leaf/spine fabric — one domain per *pod* (its servers' resources,
///   leaf port pools, and uplink halves) plus one domain per spine, with
///   the unused flat-prefix `TorRail` resources parked in a spare domain.
///
/// Every route the path planner emits crosses at most 4 domains (source
/// server/pod, destination server/pod, one fabric tier), which the engine
/// exploits with an inline per-flow domain array.
#[derive(Debug, Clone, Default)]
pub struct RateDomains {
    /// resource → domain id; empty ⇒ a single global domain 0.
    pub domain_of: Vec<u32>,
    pub n_domains: u32,
}

impl RateDomains {
    /// The trivial partition: one global domain (no aggregation).
    pub fn single() -> RateDomains {
        RateDomains { domain_of: Vec::new(), n_domains: 1 }
    }

    #[inline]
    pub fn domain(&self, r: ResourceId) -> u32 {
        if self.domain_of.is_empty() {
            0
        } else {
            self.domain_of[r]
        }
    }
}

/// Static description of one resource.
#[derive(Debug, Clone)]
pub struct ResourceSpec {
    pub key: ResourceKey,
    /// Capacity in bytes/s.
    pub capacity: f64,
    /// Per-hop latency contribution in seconds.
    pub latency: f64,
}

/// Cluster shape + link speeds. All bandwidths in bytes/s, latencies in
/// seconds.
#[derive(Debug, Clone)]
pub struct TopologyConfig {
    pub n_servers: usize,
    pub gpus_per_server: usize,
    pub nics_per_server: usize,
    /// Per-NIC unidirectional bandwidth.
    pub nic_bw: f64,
    /// Per-GPU aggregate NVLink unidirectional bandwidth.
    pub nvlink_bw: f64,
    /// Per PCIe lane (GPU↔NIC) unidirectional bandwidth.
    pub pcie_bw: f64,
    /// Cross-socket interconnect unidirectional bandwidth.
    pub upi_bw: f64,
    /// Inter-node fabric hop latency (the α in α-β models).
    pub link_latency: f64,
    /// NVLink hop latency.
    pub nvlink_latency: f64,
    /// PCIe hop latency.
    pub pcie_latency: f64,
    /// Number of NUMA sockets per server.
    pub numa_per_server: usize,
}

impl TopologyConfig {
    /// The paper's physical testbed: 2 servers × 8 H100 SXM5, 8× ConnectX-7
    /// 400 Gb/s InfiniBand, NVLink 4.0 (900 GB/s bidirectional → 450 GB/s
    /// per direction), PCIe Gen5 x16 (~64 GB/s), 2 sockets.
    pub fn testbed_h100() -> Self {
        TopologyConfig {
            n_servers: 2,
            gpus_per_server: 8,
            nics_per_server: 8,
            nic_bw: 50.0e9,     // 400 Gb/s
            nvlink_bw: 450.0e9, // per direction
            pcie_bw: 64.0e9,
            upi_bw: 40.0e9,
            link_latency: 5.0e-6,
            nvlink_latency: 1.0e-6,
            pcie_latency: 1.5e-6,
            numa_per_server: 2,
        }
    }

    /// The paper's SimAI configuration: 8×A100 + 8×200 Gb/s NICs per server
    /// on a Spectrum-X rail-optimised RoCE fabric.
    pub fn simai_a100(n_servers: usize) -> Self {
        TopologyConfig {
            n_servers,
            gpus_per_server: 8,
            nics_per_server: 8,
            nic_bw: 25.0e9,     // 200 Gb/s
            nvlink_bw: 300.0e9, // NVLink 3.0 per direction
            pcie_bw: 32.0e9,    // Gen4 x16
            upi_bw: 30.0e9,
            link_latency: 5.0e-6,
            nvlink_latency: 1.0e-6,
            pcie_latency: 1.5e-6,
            numa_per_server: 2,
        }
    }
}

/// Immutable topology: resource table + index maps + locality helpers.
#[derive(Debug, Clone)]
pub struct Topology {
    pub cfg: TopologyConfig,
    resources: Vec<ResourceSpec>,
    index: HashMap<ResourceKey, ResourceId>,
    /// The inter-server fabric this topology is built over (ideal = flat).
    fabric: Fabric,
    /// Precomputed per-GPU failover chains (§4.3 / §7 ordering), laid out
    /// flat as `nics_per_server` entries per GPU. Built once here instead
    /// of allocating a fresh `Vec` on every call inside the migration hot
    /// path.
    failover: Vec<NicId>,
    /// Base capacities, shared with every engine built over this topology
    /// (the engine's sparse state keeps no per-engine capacity copy).
    caps: Arc<[f64]>,
    /// Tier-keyed rate-domain partition for hierarchical aggregation.
    domains: Arc<RateDomains>,
}

impl Topology {
    /// Build over the degenerate flat fabric (bit-identical to the
    /// historical behaviour; see [`Topology::build_with_fabric`]).
    pub fn build(cfg: &TopologyConfig) -> Topology {
        Topology::build_with_fabric(cfg, &FabricConfig::ideal())
    }

    /// Build the resource table over a chosen inter-server fabric. The flat
    /// resources are registered first in their historical order, so an
    /// `Ideal` fabric produces exactly the historical table (ids, keys,
    /// capacities, latencies); a leaf/spine fabric *appends* its switch
    /// tier — leaf port pools, spines, uplinks — after them.
    pub fn build_with_fabric(cfg: &TopologyConfig, fabric_cfg: &FabricConfig) -> Topology {
        assert!(cfg.n_servers >= 1);
        assert!(cfg.gpus_per_server >= 1);
        assert!(cfg.nics_per_server >= 1);
        assert!(
            cfg.gpus_per_server % cfg.numa_per_server == 0
                && cfg.nics_per_server % cfg.numa_per_server == 0,
            "NUMA sockets must evenly split GPUs and NICs"
        );
        let mut resources = Vec::new();
        let mut index = HashMap::new();
        let mut add = |key: ResourceKey, capacity: f64, latency: f64| {
            let id = resources.len();
            resources.push(ResourceSpec { key, capacity, latency });
            index.insert(key, id);
        };
        let n_gpus = cfg.n_servers * cfg.gpus_per_server;
        let n_nics = cfg.n_servers * cfg.nics_per_server;
        for n in 0..n_nics {
            add(ResourceKey::NicTx(n), cfg.nic_bw, cfg.link_latency / 2.0);
            add(ResourceKey::NicRx(n), cfg.nic_bw, cfg.link_latency / 2.0);
            add(ResourceKey::PcieUp(n), cfg.pcie_bw, cfg.pcie_latency);
            add(ResourceKey::PcieDown(n), cfg.pcie_bw, cfg.pcie_latency);
        }
        for g in 0..n_gpus {
            add(ResourceKey::NvlTx(g), cfg.nvlink_bw, cfg.nvlink_latency);
            add(ResourceKey::NvlRx(g), cfg.nvlink_bw, cfg.nvlink_latency);
        }
        for s in 0..cfg.n_servers {
            add(ResourceKey::Upi(s, 0), cfg.upi_bw, cfg.pcie_latency);
            add(ResourceKey::Upi(s, 1), cfg.upi_bw, cfg.pcie_latency);
        }
        // Rail ToRs are provisioned non-blocking: full bisection for the rail.
        let tor_cap = cfg.nic_bw * cfg.n_servers as f64;
        for r in 0..cfg.nics_per_server {
            add(ResourceKey::TorRail(r), tor_cap, 0.0);
        }
        // Switch tier of a leaf/spine fabric, appended after the flat
        // resources so flat ids are untouched. Latencies come from the
        // fabric's per-hop specs — fabric depth is visible in
        // `path_latency` sums.
        let fabric = Fabric::build(cfg, fabric_cfg);
        if !fabric.is_ideal() {
            for l in 0..fabric.n_leaves() {
                add(ResourceKey::LeafIn(l), fabric.leaf_cap, fabric.switch_latency);
                add(ResourceKey::LeafOut(l), fabric.leaf_cap, fabric.switch_latency);
            }
            for s in 0..fabric.n_spines() {
                add(ResourceKey::SpineSw(s), fabric.spine_cap, fabric.switch_latency);
            }
            for l in 0..fabric.n_leaves() {
                for s in 0..fabric.n_spines() {
                    add(ResourceKey::UplinkTx(l, s), fabric.uplink_cap, fabric.uplink_latency);
                    add(ResourceKey::UplinkRx(l, s), fabric.uplink_cap, fabric.uplink_latency);
                }
            }
        }
        // Tier-keyed rate domains (see [`RateDomains`]): flat fabrics get
        // one domain per server + one per rail ToR; leaf/spine fabrics one
        // per pod + one per spine + a parking domain for the unused
        // flat-prefix ToRs.
        let (n_domains, domain_of): (u32, Vec<u32>) = if fabric.is_ideal() {
            let server_doms = cfg.n_servers as u32;
            let n = server_doms + cfg.nics_per_server as u32;
            let map = resources
                .iter()
                .map(|r| match r.key {
                    ResourceKey::NicTx(n)
                    | ResourceKey::NicRx(n)
                    | ResourceKey::PcieUp(n)
                    | ResourceKey::PcieDown(n) => (n / cfg.nics_per_server) as u32,
                    ResourceKey::NvlTx(g) | ResourceKey::NvlRx(g) => {
                        (g / cfg.gpus_per_server) as u32
                    }
                    ResourceKey::Upi(s, _) => s as u32,
                    ResourceKey::TorRail(rail) => server_doms + rail as u32,
                    _ => unreachable!("switch-tier key on an ideal fabric"),
                })
                .collect();
            (n, map)
        } else {
            let pods = fabric.n_pods() as u32;
            let spine_base = pods;
            let parking = pods + fabric.n_spines() as u32;
            let n = parking + 1;
            let pod_of_leaf = |l: LeafId| (l / cfg.nics_per_server) as u32;
            let map = resources
                .iter()
                .map(|r| match r.key {
                    ResourceKey::NicTx(n)
                    | ResourceKey::NicRx(n)
                    | ResourceKey::PcieUp(n)
                    | ResourceKey::PcieDown(n) => {
                        fabric.pod_of_server(n / cfg.nics_per_server) as u32
                    }
                    ResourceKey::NvlTx(g) | ResourceKey::NvlRx(g) => {
                        fabric.pod_of_server(g / cfg.gpus_per_server) as u32
                    }
                    ResourceKey::Upi(s, _) => fabric.pod_of_server(s) as u32,
                    // Leaf/spine routes never cross TorRail; park them.
                    ResourceKey::TorRail(_) => parking,
                    ResourceKey::LeafIn(l) | ResourceKey::LeafOut(l) => pod_of_leaf(l),
                    ResourceKey::UplinkTx(l, _) | ResourceKey::UplinkRx(l, _) => pod_of_leaf(l),
                    ResourceKey::SpineSw(sp) => spine_base + sp as u32,
                })
                .collect();
            (n, map)
        };
        let caps: Arc<[f64]> = resources.iter().map(|r| r.capacity).collect();
        let domains = Arc::new(RateDomains { domain_of, n_domains });
        let mut topo = Topology {
            cfg: cfg.clone(),
            resources,
            index,
            fabric,
            failover: Vec::new(),
            caps,
            domains,
        };
        let mut failover = Vec::with_capacity(n_gpus * cfg.nics_per_server);
        for g in 0..n_gpus {
            let mut nics: Vec<NicId> = topo.nics_of_server(topo.server_of_gpu(g)).collect();
            nics.sort_by_key(|&n| (topo.pcie_distance(g, n), n));
            failover.extend_from_slice(&nics);
        }
        topo.failover = failover;
        topo
    }

    /// The inter-server fabric the topology is built over.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Base capacities as a shared slice — engines over this topology hold
    /// a clone of the `Arc`, not a copy of the table.
    pub fn shared_caps(&self) -> Arc<[f64]> {
        Arc::clone(&self.caps)
    }

    /// The tier-keyed rate-domain partition (hierarchical aggregation).
    pub fn rate_domains(&self) -> Arc<RateDomains> {
        Arc::clone(&self.domains)
    }

    // ------------------------------------------------------------------
    // Counting / lookup
    // ------------------------------------------------------------------

    pub fn n_servers(&self) -> usize {
        self.cfg.n_servers
    }

    pub fn n_gpus(&self) -> usize {
        self.cfg.n_servers * self.cfg.gpus_per_server
    }

    pub fn n_nics(&self) -> usize {
        self.cfg.n_servers * self.cfg.nics_per_server
    }

    pub fn n_resources(&self) -> usize {
        self.resources.len()
    }

    pub fn resources(&self) -> &[ResourceSpec] {
        &self.resources
    }

    pub fn resource(&self, key: ResourceKey) -> ResourceId {
        *self
            .index
            .get(&key)
            .unwrap_or_else(|| panic!("unknown resource {key:?}"))
    }

    pub fn spec(&self, id: ResourceId) -> &ResourceSpec {
        &self.resources[id]
    }

    // ------------------------------------------------------------------
    // Locality
    // ------------------------------------------------------------------

    pub fn server_of_gpu(&self, g: GpuId) -> ServerId {
        g / self.cfg.gpus_per_server
    }

    pub fn server_of_nic(&self, n: NicId) -> ServerId {
        n / self.cfg.nics_per_server
    }

    pub fn gpu_local(&self, g: GpuId) -> usize {
        g % self.cfg.gpus_per_server
    }

    pub fn nic_local(&self, n: NicId) -> usize {
        n % self.cfg.nics_per_server
    }

    /// Rail of a NIC (rail-optimised fabric: rail == local index).
    pub fn rail_of_nic(&self, n: NicId) -> RailId {
        self.nic_local(n)
    }

    pub fn gpus_of_server(&self, s: ServerId) -> std::ops::Range<GpuId> {
        s * self.cfg.gpus_per_server..(s + 1) * self.cfg.gpus_per_server
    }

    pub fn nics_of_server(&self, s: ServerId) -> std::ops::Range<NicId> {
        s * self.cfg.nics_per_server..(s + 1) * self.cfg.nics_per_server
    }

    /// The affinity NIC of a GPU (same PCIe switch).
    pub fn affinity_nic(&self, g: GpuId) -> NicId {
        let s = self.server_of_gpu(g);
        let local = self.gpu_local(g) % self.cfg.nics_per_server;
        s * self.cfg.nics_per_server + local
    }

    /// The GPU co-located with a NIC (the PXN proxy target for that NIC).
    pub fn affinity_gpu(&self, n: NicId) -> GpuId {
        let s = self.server_of_nic(n);
        let local = self.nic_local(n) % self.cfg.gpus_per_server;
        s * self.cfg.gpus_per_server + local
    }

    pub fn numa_of_gpu(&self, g: GpuId) -> usize {
        let per = self.cfg.gpus_per_server / self.cfg.numa_per_server;
        self.gpu_local(g) / per
    }

    pub fn numa_of_nic(&self, n: NicId) -> usize {
        let per = self.cfg.nics_per_server / self.cfg.numa_per_server;
        self.nic_local(n) / per
    }

    /// PCIe "distance" between a GPU and a NIC on the same server, used to
    /// order failover chains (§7 of the paper: backup NICs ordered by PCIe
    /// distance; closest healthy NIC is activated first).
    /// 0 = affinity pair, 1 = same NUMA socket, 2 = cross-socket.
    pub fn pcie_distance(&self, g: GpuId, n: NicId) -> u32 {
        assert_eq!(
            self.server_of_gpu(g),
            self.server_of_nic(n),
            "pcie_distance is intra-server"
        );
        if self.affinity_nic(g) == n {
            0
        } else if self.numa_of_gpu(g) == self.numa_of_nic(n) {
            1
        } else {
            2
        }
    }

    /// NICs of the GPU's server ordered by PCIe distance (then index): the
    /// failover chain of §4.3 / §7. Precomputed at build time — the
    /// migration hot path reads a slice instead of sorting a fresh `Vec`
    /// per call.
    pub fn failover_chain(&self, g: GpuId) -> &[NicId] {
        let k = self.cfg.nics_per_server;
        &self.failover[g * k..(g + 1) * k]
    }

    /// Sum of the per-hop latencies charged by each resource on the path,
    /// from the resource specs: NIC halves carry `link_latency / 2`, PCIe
    /// lanes `pcie_latency`, NVLink hops `nvlink_latency` — and switch-tier
    /// resources their fabric's per-hop leaf/spine/uplink latencies, so a
    /// deeper fabric shows up directly in completion times. Flat
    /// topologies charge `TorRail` at 0 and are bit-identical to the
    /// historical values (regression-tested in `path::tests`).
    pub fn path_latency(&self, path: &[ResourceId]) -> f64 {
        path.iter().map(|&r| self.resources[r].latency).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2x8() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn counts() {
        let t = t2x8();
        assert_eq!(t.n_gpus(), 16);
        assert_eq!(t.n_nics(), 16);
        // 16 nics * 4 + 16 gpus * 2 + 2 servers * 2 + 8 rails
        assert_eq!(t.n_resources(), 16 * 4 + 16 * 2 + 2 * 2 + 8);
    }

    #[test]
    fn affinity_is_one_to_one() {
        let t = t2x8();
        for g in 0..t.n_gpus() {
            let n = t.affinity_nic(g);
            assert_eq!(t.server_of_gpu(g), t.server_of_nic(n));
            assert_eq!(t.affinity_gpu(n), g);
        }
    }

    #[test]
    fn numa_split() {
        let t = t2x8();
        assert_eq!(t.numa_of_gpu(0), 0);
        assert_eq!(t.numa_of_gpu(3), 0);
        assert_eq!(t.numa_of_gpu(4), 1);
        assert_eq!(t.numa_of_gpu(15), 1); // gpu 15 = server1 local 7
        assert_eq!(t.numa_of_nic(12), 1);
    }

    #[test]
    fn pcie_distances() {
        let t = t2x8();
        assert_eq!(t.pcie_distance(0, 0), 0);
        assert_eq!(t.pcie_distance(0, 1), 1); // same socket
        assert_eq!(t.pcie_distance(0, 5), 2); // cross socket
        assert_eq!(t.pcie_distance(9, 9), 0); // server 1 local pair
    }

    #[test]
    fn failover_chain_ordering() {
        let t = t2x8();
        let chain = t.failover_chain(2);
        assert_eq!(chain[0], 2); // affinity first
        // then same-NUMA nics (0,1,3), then cross-NUMA (4..8)
        assert_eq!(&chain[1..4], &[0, 1, 3]);
        assert_eq!(&chain[4..], &[4, 5, 6, 7]);
        assert_eq!(chain.len(), 8);
    }

    #[test]
    fn rails_are_local_indices() {
        let t = t2x8();
        assert_eq!(t.rail_of_nic(3), 3);
        assert_eq!(t.rail_of_nic(11), 3); // server 1, local 3 → same rail
    }

    #[test]
    fn resource_lookup_roundtrip() {
        let t = t2x8();
        for id in 0..t.n_resources() {
            let key = t.spec(id).key;
            assert_eq!(t.resource(key), id);
        }
    }

    #[test]
    #[should_panic]
    fn pcie_distance_rejects_cross_server() {
        let t = t2x8();
        t.pcie_distance(0, 8);
    }

    #[test]
    fn simai_scale() {
        let t = Topology::build(&TopologyConfig::simai_a100(64));
        assert_eq!(t.n_gpus(), 512);
        assert_eq!(t.server_of_gpu(511), 63);
    }

    #[test]
    fn ideal_fabric_adds_no_resources() {
        // `Fabric::ideal()` must reproduce the flat topology bit-for-bit:
        // same resource count, same keys in the same order.
        let flat = t2x8();
        let ideal = Topology::build_with_fabric(
            &TopologyConfig::testbed_h100(),
            &crate::fabric::FabricConfig::ideal(),
        );
        assert_eq!(flat.n_resources(), ideal.n_resources());
        for id in 0..flat.n_resources() {
            assert_eq!(flat.spec(id).key, ideal.spec(id).key);
            assert_eq!(flat.spec(id).capacity, ideal.spec(id).capacity);
            assert_eq!(flat.spec(id).latency, ideal.spec(id).latency);
        }
        assert!(ideal.fabric().is_ideal());
    }

    #[test]
    fn leaf_spine_appends_switch_tier_after_flat_resources() {
        use crate::fabric::{FabricConfig, LeafSpineCfg};
        let cfg = TopologyConfig::simai_a100(16);
        let flat = Topology::build(&cfg);
        let fab = FabricConfig::leaf_spine_with(LeafSpineCfg {
            pod_size: 4,
            spines: 4,
            ..LeafSpineCfg::default()
        });
        let t = Topology::build_with_fabric(&cfg, &fab);
        // Flat prefix identical (existing resource ids are stable).
        for id in 0..flat.n_resources() {
            assert_eq!(flat.spec(id).key, t.spec(id).key);
        }
        // 32 leaves × 2 port pools + 4 spines + 32×4 uplinks × 2 dirs.
        let extra = 32 * 2 + 4 + 32 * 4 * 2;
        assert_eq!(t.n_resources(), flat.n_resources() + extra);
        // Lookup round-trips for the new keys too.
        for id in flat.n_resources()..t.n_resources() {
            let key = t.spec(id).key;
            assert_eq!(t.resource(key), id);
        }
    }

    #[test]
    fn flat_rate_domains_partition_by_server_and_rail() {
        let t = t2x8();
        let d = t.rate_domains();
        // 2 servers + 8 rails.
        assert_eq!(d.n_domains, 10);
        assert_eq!(d.domain_of.len(), t.n_resources());
        assert!(d.domain_of.iter().all(|&x| x < d.n_domains));
        assert_eq!(d.domain(t.resource(ResourceKey::NicTx(0))), 0);
        assert_eq!(d.domain(t.resource(ResourceKey::NicRx(9))), 1); // server 1
        assert_eq!(d.domain(t.resource(ResourceKey::NvlTx(15))), 1);
        assert_eq!(d.domain(t.resource(ResourceKey::Upi(0, 1))), 0);
        assert_eq!(d.domain(t.resource(ResourceKey::TorRail(3))), 2 + 3);
        // Shared caps mirror the spec table.
        let caps = t.shared_caps();
        assert_eq!(caps.len(), t.n_resources());
        for id in 0..t.n_resources() {
            assert_eq!(caps[id], t.spec(id).capacity);
        }
    }

    #[test]
    fn leaf_spine_rate_domains_partition_by_pod_and_spine() {
        use crate::fabric::{FabricConfig, LeafSpineCfg};
        let cfg = TopologyConfig::simai_a100(16);
        let fab = FabricConfig::leaf_spine_with(LeafSpineCfg {
            pod_size: 4,
            spines: 4,
            ..LeafSpineCfg::default()
        });
        let t = Topology::build_with_fabric(&cfg, &fab);
        let d = t.rate_domains();
        // 4 pods + 4 spines + 1 parking domain for the unused flat ToRs.
        assert_eq!(d.n_domains, 9);
        assert!(d.domain_of.iter().all(|&x| x < d.n_domains));
        // Server 5 is in pod 1; its NICs/GPUs/UPI share the pod domain with
        // its leaves and uplink halves.
        assert_eq!(d.domain(t.resource(ResourceKey::NicTx(5 * 8))), 1);
        assert_eq!(d.domain(t.resource(ResourceKey::NvlRx(5 * 8 + 7))), 1);
        assert_eq!(d.domain(t.resource(ResourceKey::LeafIn(8))), 1); // leaf 8 = pod 1 rail 0
        assert_eq!(d.domain(t.resource(ResourceKey::UplinkTx(8, 2))), 1);
        assert_eq!(d.domain(t.resource(ResourceKey::SpineSw(2))), 4 + 2);
        assert_eq!(d.domain(t.resource(ResourceKey::TorRail(0))), 8);
        // Any planner route crosses at most 4 distinct domains — the
        // engine's inline per-flow domain array depends on this.
        let cross_pod = path::Route::default_inter(&t, 0, 127).plan(&t, 0, 127);
        let adjacent = path::Route::default_inter(&t, 0, 32).plan(&t, 0, 32);
        for plan in [&cross_pod, &adjacent] {
            let mut doms: Vec<u32> = plan.path.iter().map(|&r| d.domain(r)).collect();
            doms.sort_unstable();
            doms.dedup();
            assert!(doms.len() <= 4, "route crosses {} domains", doms.len());
        }
    }

    #[test]
    fn failover_chain_is_cached_and_stable() {
        let t = t2x8();
        for g in 0..t.n_gpus() {
            // The cached slice must equal a fresh sort (the pre-cache
            // behaviour).
            let mut fresh: Vec<NicId> = t.nics_of_server(t.server_of_gpu(g)).collect();
            fresh.sort_by_key(|&n| (t.pcie_distance(g, n), n));
            assert_eq!(t.failover_chain(g), fresh.as_slice(), "gpu {g}");
        }
    }
}
