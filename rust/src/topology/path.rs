//! Route construction: turn (src GPU, dst GPU, chosen NICs, forwarding
//! modes) into a concrete resource path for the fluid-flow engine.
//!
//! The forwarding modes mirror §5.1 of the paper (PXN- and NUMA-aware load
//! balancing): a GPU reaching a non-affinity NIC either forwards over PCIe
//! (same socket), PCIe + UPI (cross socket), or relays via NVLink through
//! the proxy GPU co-located with the target NIC (PXN).

use super::{GpuId, NicId, ResourceId, ResourceKey, Topology};

/// How a GPU's traffic reaches a NIC on its own server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Forward {
    /// The GPU's own affinity NIC: plain PCIe lane.
    Affinity,
    /// Direct PCIe forwarding to a same-socket NIC.
    Pcie,
    /// PCIe across the socket interconnect (QPI/UPI) to a remote-socket NIC.
    PcieUpi,
    /// NVLink relay through the proxy GPU co-located with the NIC (PXN).
    Pxn,
}

/// A fully-specified route between two GPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Same server: NVLink only.
    Intra,
    /// Different servers: src GPU → src NIC → fabric → dst NIC → dst GPU.
    Inter {
        src_nic: NicId,
        dst_nic: NicId,
        src_fwd: Forward,
        dst_fwd: Forward,
    },
}

/// A planned route: the resource path plus its end-to-end latency.
#[derive(Debug, Clone)]
pub struct RoutePlan {
    pub route: Route,
    pub path: Vec<ResourceId>,
    pub latency: f64,
}

impl Route {
    /// The default inter-server route between two GPUs using their affinity
    /// NICs (NCCL's steady-state binding).
    pub fn default_inter(topo: &Topology, src: GpuId, dst: GpuId) -> Route {
        debug_assert_ne!(topo.server_of_gpu(src), topo.server_of_gpu(dst));
        Route::Inter {
            src_nic: topo.affinity_nic(src),
            dst_nic: topo.affinity_nic(dst),
            src_fwd: Forward::Affinity,
            dst_fwd: Forward::Affinity,
        }
    }

    /// Pick the natural forwarding mode for a (GPU, NIC) pair per the
    /// paper's default policy: affinity → PCIe lane; same NUMA → direct
    /// PCIe; cross NUMA → PXN relay (preferred over UPI unless the planner
    /// overrides, see `schedule::balance`).
    pub fn auto_forward(topo: &Topology, g: GpuId, n: NicId) -> Forward {
        match topo.pcie_distance(g, n) {
            0 => Forward::Affinity,
            1 => Forward::Pcie,
            _ => Forward::Pxn,
        }
    }

    /// Build the route between two GPUs, choosing Intra vs Inter and
    /// forwarding automatically given the NICs to use.
    pub fn between(topo: &Topology, src: GpuId, dst: GpuId, src_nic: NicId, dst_nic: NicId) -> Route {
        if topo.server_of_gpu(src) == topo.server_of_gpu(dst) {
            Route::Intra
        } else {
            Route::Inter {
                src_nic,
                dst_nic,
                src_fwd: Self::auto_forward(topo, src, src_nic),
                dst_fwd: Self::auto_forward(topo, dst, dst_nic),
            }
        }
    }

    /// Materialise the resource path for this route.
    pub fn plan(&self, topo: &Topology, src: GpuId, dst: GpuId) -> RoutePlan {
        let mut path = Vec::with_capacity(10);
        match *self {
            Route::Intra => {
                assert_eq!(
                    topo.server_of_gpu(src),
                    topo.server_of_gpu(dst),
                    "Intra route across servers"
                );
                if src != dst {
                    path.push(topo.resource(ResourceKey::NvlTx(src)));
                    path.push(topo.resource(ResourceKey::NvlRx(dst)));
                }
            }
            Route::Inter { src_nic, dst_nic, src_fwd, dst_fwd } => {
                assert_ne!(
                    topo.server_of_gpu(src),
                    topo.server_of_gpu(dst),
                    "Inter route within one server"
                );
                assert_eq!(topo.server_of_gpu(src), topo.server_of_nic(src_nic));
                assert_eq!(topo.server_of_gpu(dst), topo.server_of_nic(dst_nic));
                // Source side: GPU → NIC.
                Self::push_fwd_path(topo, &mut path, src, src_nic, src_fwd, true);
                // Fabric: NIC tx → switched fabric → NIC rx.
                path.push(topo.resource(ResourceKey::NicTx(src_nic)));
                push_fabric_hop(topo, &mut path, src_nic, dst_nic);
                path.push(topo.resource(ResourceKey::NicRx(dst_nic)));
                // Destination side: NIC → GPU.
                Self::push_fwd_path(topo, &mut path, dst, dst_nic, dst_fwd, false);
            }
        }
        let latency = topo.path_latency(&path);
        RoutePlan { route: *self, path, latency }
    }

    fn push_fwd_path(
        topo: &Topology,
        path: &mut Vec<ResourceId>,
        gpu: GpuId,
        nic: NicId,
        fwd: Forward,
        towards_nic: bool,
    ) {
        let server = topo.server_of_gpu(gpu);
        let lane = |n| {
            if towards_nic {
                ResourceKey::PcieUp(n)
            } else {
                ResourceKey::PcieDown(n)
            }
        };
        match fwd {
            Forward::Affinity => {
                debug_assert_eq!(topo.pcie_distance(gpu, nic), 0);
                path.push(topo.resource(lane(nic)));
            }
            Forward::Pcie => {
                debug_assert!(topo.pcie_distance(gpu, nic) <= 1);
                path.push(topo.resource(lane(nic)));
            }
            Forward::PcieUpi => {
                // Direction of the UPI hop depends on which socket the GPU
                // sits on and which direction the data moves.
                let gpu_socket = topo.numa_of_gpu(gpu) as u8;
                let dir = if towards_nic { gpu_socket } else { 1 - gpu_socket };
                path.push(topo.resource(ResourceKey::Upi(server, dir)));
                path.push(topo.resource(lane(nic)));
            }
            Forward::Pxn => {
                // GPU → NVLink → proxy GPU → PCIe lane → NIC (and mirrored
                // on the receive side).
                let proxy = topo.affinity_gpu(nic);
                if towards_nic {
                    path.push(topo.resource(ResourceKey::NvlTx(gpu)));
                    path.push(topo.resource(ResourceKey::NvlRx(proxy)));
                    path.push(topo.resource(lane(nic)));
                } else {
                    path.push(topo.resource(lane(nic)));
                    path.push(topo.resource(ResourceKey::NvlTx(proxy)));
                    path.push(topo.resource(ResourceKey::NvlRx(gpu)));
                }
            }
        }
    }
}

/// Expand the inter-server NIC→NIC hop into the concrete fabric resource
/// chain (everything between `NicTx(src)` and `NicRx(dst)`).
///
/// * Flat / ideal fabric: the historical rail expansion — the source rail's
///   ToR, plus the destination rail's ToR for cross-rail traffic. Byte-
///   identical to the pre-fabric behaviour, so existing plans and golden
///   traces are unchanged.
/// * Leaf/spine fabric: same-leaf traffic (same rail, same pod) switches
///   locally through the leaf's port pools; everything else climbs the
///   source leaf's ECMP-chosen uplink to a spine and descends the
///   destination leaf's downlink from that same spine. The spine pick is a
///   deterministic seeded hash of the NIC pair
///   ([`crate::fabric::Fabric::ecmp_spine`]).
pub fn push_fabric_hop(
    topo: &Topology,
    path: &mut Vec<super::ResourceId>,
    src_nic: NicId,
    dst_nic: NicId,
) {
    let fabric = topo.fabric();
    if fabric.is_ideal() {
        let r_src = topo.rail_of_nic(src_nic);
        let r_dst = topo.rail_of_nic(dst_nic);
        path.push(topo.resource(ResourceKey::TorRail(r_src)));
        if r_dst != r_src {
            // Cross-rail traffic traverses the spine: both leaf
            // switches are on the path.
            path.push(topo.resource(ResourceKey::TorRail(r_dst)));
        }
        return;
    }
    let l_src = fabric.leaf_of_nic(src_nic);
    let l_dst = fabric.leaf_of_nic(dst_nic);
    path.push(topo.resource(ResourceKey::LeafIn(l_src)));
    if l_src != l_dst {
        let spine = fabric.ecmp_spine(src_nic, dst_nic);
        path.push(topo.resource(ResourceKey::UplinkTx(l_src, spine)));
        path.push(topo.resource(ResourceKey::SpineSw(spine)));
        path.push(topo.resource(ResourceKey::UplinkRx(l_dst, spine)));
    }
    path.push(topo.resource(ResourceKey::LeafOut(l_dst)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LeafSpineCfg};
    use crate::topology::TopologyConfig;

    fn t() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn intra_path_uses_nvlink_only() {
        let t = t();
        let plan = Route::Intra.plan(&t, 0, 3);
        assert_eq!(plan.path.len(), 2);
        assert_eq!(t.spec(plan.path[0]).key, ResourceKey::NvlTx(0));
        assert_eq!(t.spec(plan.path[1]).key, ResourceKey::NvlRx(3));
    }

    #[test]
    fn intra_self_is_empty() {
        let t = t();
        let plan = Route::Intra.plan(&t, 5, 5);
        assert!(plan.path.is_empty());
        assert_eq!(plan.latency, 0.0);
    }

    #[test]
    fn default_inter_same_rail() {
        let t = t();
        // GPU 2 (server 0) → GPU 10 (server 1, local 2): same rail 2.
        let plan = Route::default_inter(&t, 2, 10).plan(&t, 2, 10);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        assert_eq!(
            keys,
            vec![
                ResourceKey::PcieUp(2),
                ResourceKey::NicTx(2),
                ResourceKey::TorRail(2),
                ResourceKey::NicRx(10),
                ResourceKey::PcieDown(10),
            ]
        );
        assert!(plan.latency > 0.0);
    }

    #[test]
    fn cross_rail_adds_second_tor() {
        let t = t();
        let route = Route::Inter {
            src_nic: 0,
            dst_nic: 9, // rail 1 on server 1
            src_fwd: Forward::Affinity,
            dst_fwd: Forward::Pcie,
        };
        let plan = route.plan(&t, 0, 9);
        let tor_hops = plan
            .path
            .iter()
            .filter(|&&r| matches!(t.spec(r).key, ResourceKey::TorRail(_)))
            .count();
        assert_eq!(tor_hops, 2);
    }

    #[test]
    fn pxn_path_relays_through_proxy() {
        let t = t();
        // GPU 0 sends via NIC 7 (cross-socket) using PXN: proxy is GPU 7.
        let route = Route::Inter {
            src_nic: 7,
            dst_nic: 15,
            src_fwd: Forward::Pxn,
            dst_fwd: Forward::Affinity,
        };
        let plan = route.plan(&t, 0, 15);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        assert!(keys.contains(&ResourceKey::NvlTx(0)));
        assert!(keys.contains(&ResourceKey::NvlRx(7)));
        assert!(keys.contains(&ResourceKey::PcieUp(7)));
    }

    #[test]
    fn upi_path_crosses_socket() {
        let t = t();
        let route = Route::Inter {
            src_nic: 7,
            dst_nic: 15,
            src_fwd: Forward::PcieUpi,
            dst_fwd: Forward::Affinity,
        };
        let plan = route.plan(&t, 0, 15);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        assert!(keys.contains(&ResourceKey::Upi(0, 0)));
    }

    #[test]
    fn auto_forward_policy() {
        let t = t();
        assert_eq!(Route::auto_forward(&t, 0, 0), Forward::Affinity);
        assert_eq!(Route::auto_forward(&t, 0, 2), Forward::Pcie);
        assert_eq!(Route::auto_forward(&t, 0, 6), Forward::Pxn);
    }

    fn leaf_spine_16() -> Topology {
        Topology::build_with_fabric(
            &TopologyConfig::simai_a100(16),
            &FabricConfig::leaf_spine_with(LeafSpineCfg {
                pod_size: 4,
                spines: 4,
                ..LeafSpineCfg::default()
            }),
        )
    }

    #[test]
    fn flat_path_latency_regression() {
        // Satellite guard: flat topologies charge per-hop latency from the
        // resource specs, and the values are the historical constants —
        // PCIe lane + NIC halves (= link_latency) + zero-latency rail ToRs.
        let t = t();
        let cfg = &t.cfg;
        let plan = Route::default_inter(&t, 2, 10).plan(&t, 2, 10);
        let want = cfg.pcie_latency + cfg.link_latency + cfg.pcie_latency;
        assert!((plan.latency - want).abs() < 1e-15, "{} != {want}", plan.latency);
        // Cross-rail adds a second zero-latency ToR: the latency must not
        // change on flat fabrics.
        let route = Route::Inter {
            src_nic: 0,
            dst_nic: 9,
            src_fwd: Forward::Affinity,
            dst_fwd: Forward::Pcie,
        };
        let plan = route.plan(&t, 0, 9);
        assert!((plan.latency - want).abs() < 1e-15);
    }

    #[test]
    fn leaf_spine_same_leaf_switches_locally() {
        let t = leaf_spine_16();
        // GPU 2 (server 0) → GPU 2+8 (server 1): same rail 2, same pod.
        let plan = Route::default_inter(&t, 2, 10).plan(&t, 2, 10);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        assert_eq!(
            keys,
            vec![
                ResourceKey::PcieUp(2),
                ResourceKey::NicTx(2),
                ResourceKey::LeafIn(2),
                ResourceKey::LeafOut(2),
                ResourceKey::NicRx(10),
                ResourceKey::PcieDown(10),
            ]
        );
        // Fabric depth is visible: two switch hops on top of the flat sum.
        let flat_want = t.cfg.pcie_latency * 2.0 + t.cfg.link_latency;
        let f = t.fabric();
        assert!((plan.latency - (flat_want + 2.0 * f.switch_latency)).abs() < 1e-15);
    }

    #[test]
    fn leaf_spine_cross_pod_crosses_one_spine() {
        let t = leaf_spine_16();
        let f = t.fabric();
        // Server 0 rail 0 → server 8 rail 0: same rail, different pods.
        let src_nic = 0;
        let dst_nic = 8 * 8;
        let src = 0;
        let dst = 8 * 8;
        let plan = Route::between(&t, src, dst, src_nic, dst_nic).plan(&t, src, dst);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        let spine = f.ecmp_spine(src_nic, dst_nic);
        let l_src = f.leaf_of_nic(src_nic);
        let l_dst = f.leaf_of_nic(dst_nic);
        assert_ne!(l_src, l_dst);
        assert!(keys.contains(&ResourceKey::LeafIn(l_src)));
        assert!(keys.contains(&ResourceKey::UplinkTx(l_src, spine)));
        assert!(keys.contains(&ResourceKey::SpineSw(spine)));
        assert!(keys.contains(&ResourceKey::UplinkRx(l_dst, spine)));
        assert!(keys.contains(&ResourceKey::LeafOut(l_dst)));
        // Exactly one spine on the path.
        let spines = keys.iter().filter(|k| matches!(k, ResourceKey::SpineSw(_))).count();
        assert_eq!(spines, 1);
        // Depth: 3 switch hops + 2 uplink hops beyond the flat latency.
        let flat_want = t.cfg.pcie_latency * 2.0 + t.cfg.link_latency;
        let want = flat_want + 3.0 * f.switch_latency + 2.0 * f.uplink_latency;
        assert!((plan.latency - want).abs() < 1e-15);
    }

    #[test]
    fn ideal_fabric_hop_matches_flat_expansion() {
        // The degenerate fabric must expand to the literal historical rail
        // path for every NIC pair.
        let t = t();
        for src_nic in 0..8usize {
            for dst_nic in 8..16usize {
                let mut path = Vec::new();
                push_fabric_hop(&t, &mut path, src_nic, dst_nic);
                let r_src = t.rail_of_nic(src_nic);
                let r_dst = t.rail_of_nic(dst_nic);
                let mut want = vec![t.resource(ResourceKey::TorRail(r_src))];
                if r_dst != r_src {
                    want.push(t.resource(ResourceKey::TorRail(r_dst)));
                }
                assert_eq!(path, want, "{src_nic}->{dst_nic}");
            }
        }
    }

    #[test]
    fn pxn_receive_side_mirrors() {
        let t = t();
        let route = Route::Inter {
            src_nic: 0,
            dst_nic: 15,
            src_fwd: Forward::Affinity,
            dst_fwd: Forward::Pxn,
        };
        // Receiver GPU 8 (server 1 socket 0) receives via NIC 15 (socket 1):
        // NIC → PCIe down → proxy GPU 15 → NVLink → GPU 8.
        let plan = route.plan(&t, 0, 8);
        let keys: Vec<_> = plan.path.iter().map(|&r| t.spec(r).key).collect();
        let pos_pcie = keys.iter().position(|k| *k == ResourceKey::PcieDown(15)).unwrap();
        let pos_nvl = keys.iter().position(|k| *k == ResourceKey::NvlRx(8)).unwrap();
        assert!(pos_pcie < pos_nvl);
    }
}
