//! Rank sets: the substrate of process-group (communicator-group)
//! collectives.
//!
//! NCCL jobs never run one world-scope communicator: a Megatron TP8/PP2
//! layout drives tensor-parallel AllReduce on intra-server groups, pipeline
//! SendRecv on stage pairs and data-parallel AllReduce on replica groups —
//! each over a *subset* of ranks, all sharing the same NICs and fault
//! domain. A [`RankSet`] is the immutable description of one such subset:
//! the sorted member ranks, grouped per server, with the per-server "lead"
//! rank the R² tailored-broadcast stage injects through.
//!
//! Ordering convention: ranks are kept sorted (ascending GPU id) and
//! servers ascending, so the world rank set reproduces NCCL's default ring
//! order exactly — a group over ranks `[0..n_gpus)` compiles bit-identical
//! schedules to the world-scope path (property-tested in
//! `rust/tests/prop_groups.rs`).

use super::{GpuId, ServerId, Topology};

/// An immutable, validated set of ranks (global GPU ids) participating in
/// a group collective.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankSet {
    /// Member ranks, sorted ascending.
    ranks: Vec<GpuId>,
    /// Servers hosting at least one member, sorted ascending.
    servers: Vec<ServerId>,
    /// Member ranks per server, parallel to `servers` (each sorted).
    by_server: Vec<Vec<GpuId>>,
    gpus_per_server: usize,
}

impl RankSet {
    /// Build a rank set. Ranks must be non-empty, unique and within the
    /// topology; they are sorted internally (group identity is the *set*).
    pub fn new(topo: &Topology, ranks: &[GpuId]) -> RankSet {
        assert!(!ranks.is_empty(), "rank set must be non-empty");
        let mut sorted = ranks.to_vec();
        sorted.sort_unstable();
        assert!(
            sorted.windows(2).all(|w| w[0] != w[1]),
            "rank set contains duplicates: {sorted:?}"
        );
        assert!(
            *sorted.last().unwrap() < topo.n_gpus(),
            "rank {} out of range (topology has {} GPUs)",
            sorted.last().unwrap(),
            topo.n_gpus()
        );
        let g = topo.cfg.gpus_per_server;
        let mut servers: Vec<ServerId> = Vec::new();
        let mut by_server: Vec<Vec<GpuId>> = Vec::new();
        for &r in &sorted {
            let s = r / g;
            if servers.last() != Some(&s) {
                servers.push(s);
                by_server.push(Vec::new());
            }
            by_server.last_mut().unwrap().push(r);
        }
        RankSet { ranks: sorted, servers, by_server, gpus_per_server: g }
    }

    /// The world rank set: every GPU of the topology.
    pub fn world(topo: &Topology) -> RankSet {
        let ranks: Vec<GpuId> = (0..topo.n_gpus()).collect();
        RankSet::new(topo, &ranks)
    }

    /// Member ranks, sorted ascending.
    pub fn ranks(&self) -> &[GpuId] {
        &self.ranks
    }

    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Servers hosting at least one member rank, sorted ascending.
    pub fn servers(&self) -> &[ServerId] {
        &self.servers
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    /// Member ranks on one server (empty when the server hosts none).
    pub fn ranks_on(&self, server: ServerId) -> &[GpuId] {
        match self.servers.binary_search(&server) {
            Ok(i) => &self.by_server[i],
            Err(_) => &[],
        }
    }

    /// The group's lead rank on a server (lowest member id): the rank the
    /// R² tailored-broadcast stage injects and delivers through.
    pub fn lead(&self, server: ServerId) -> Option<GpuId> {
        self.ranks_on(server).first().copied()
    }

    pub fn contains(&self, rank: GpuId) -> bool {
        self.ranks.binary_search(&rank).is_ok()
    }

    pub fn contains_server(&self, server: ServerId) -> bool {
        self.servers.binary_search(&server).is_ok()
    }

    /// Largest member count on any single server: the chunk-pipelining
    /// depth of the group's broadcast/tree schedules (one chunk per local
    /// GPU keeps the NVLink chain saturated).
    pub fn max_ranks_per_server(&self) -> usize {
        self.by_server.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// True when the set covers the whole topology.
    pub fn is_world(&self, topo: &Topology) -> bool {
        self.ranks.len() == topo.n_gpus()
    }

    /// The subset of this rank set living on `servers` (which must all be
    /// member servers).
    pub fn restrict(&self, servers: &[ServerId]) -> RankSet {
        let mut srv: Vec<ServerId> = servers.to_vec();
        srv.sort_unstable();
        let mut ranks = Vec::new();
        let mut by_server = Vec::new();
        for &s in &srv {
            let on = self.ranks_on(s);
            assert!(!on.is_empty(), "server {s} is not a member of this rank set");
            ranks.extend_from_slice(on);
            by_server.push(on.to_vec());
        }
        RankSet { ranks, servers: srv, by_server, gpus_per_server: self.gpus_per_server }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyConfig;

    fn topo() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn world_set_covers_everything() {
        let t = topo();
        let w = RankSet::world(&t);
        assert_eq!(w.len(), 16);
        assert_eq!(w.servers(), &[0, 1]);
        assert_eq!(w.ranks_on(1), &(8..16).collect::<Vec<_>>()[..]);
        assert_eq!(w.lead(0), Some(0));
        assert_eq!(w.lead(1), Some(8));
        assert_eq!(w.max_ranks_per_server(), 8);
        assert!(w.is_world(&t));
    }

    #[test]
    fn subset_groups_by_server() {
        let t = topo();
        // A PP stage pair: rank 3 on server 0, rank 11 on server 1.
        let s = RankSet::new(&t, &[11, 3]);
        assert_eq!(s.ranks(), &[3, 11]);
        assert_eq!(s.servers(), &[0, 1]);
        assert_eq!(s.ranks_on(0), &[3]);
        assert_eq!(s.ranks_on(1), &[11]);
        assert_eq!(s.max_ranks_per_server(), 1);
        assert!(!s.is_world(&t));
        assert!(s.contains(11) && !s.contains(4));
    }

    #[test]
    fn restrict_keeps_member_servers() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let w = RankSet::world(&t);
        let sub = w.restrict(&[1, 3]);
        assert_eq!(sub.servers(), &[1, 3]);
        assert_eq!(sub.len(), 16);
        assert_eq!(sub.lead(3), Some(24));
    }

    #[test]
    #[should_panic(expected = "duplicates")]
    fn duplicate_ranks_rejected() {
        let t = topo();
        RankSet::new(&t, &[1, 2, 1]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_rejected() {
        let t = topo();
        RankSet::new(&t, &[0, 16]);
    }
}
