//! Request-level serving simulator: production traffic, continuous
//! batching and replica failover under faults.
//!
//! The training-side scenario engine answers "what does a fault cost an
//! iteration?"; this module answers the serving-side question — "what does
//! a fault cost a *request*?" — at production arrival rates:
//!
//! * [`arrivals`] — seeded Poisson / burst / trace-driven arrival
//!   processes ([`ArrivalSpec`]); same spec + seed ⇒ same requests.
//! * [`engine`] — the request engine ([`run_request_engine`]): continuous
//!   batching with per-request prefill/decode phases on prefill/decode
//!   server-pair replicas, every cross-server transfer (PD KV shipment,
//!   per-token TP allreduce) timed through the real
//!   [`crate::ccl::CommWorld`] compiled plans so scenario fault scripts
//!   perturb request latencies mid-flight. A replica-level death (a whole
//!   server, not just a NIC) re-routes queued requests, replays in-flight
//!   prefills on the survivors and counts the wasted work; requests drop
//!   only while *no* healthy replica exists.
//! * [`metrics`] — per-request records, the lost/replayed-work
//!   [`ServingLedger`] and the TTFT/TPOT/goodput [`ServingSummary`] that
//!   scenario reports serialize into golden traces.
//! * [`sweep`] — the `SERVE_*`-parameterised arrival-rate × fault-arm
//!   sweep behind the `serving_sweep` bench and the `serve-sweep` CLI
//!   subcommand.
//!
//! Everything is deterministic and seeded: serving corpora byte-compare
//! against golden fixtures, and `rust/tests/prop_serving.rs`
//! property-tests thread-count-invariant determinism and the failover
//! invariant.

pub mod arrivals;
pub mod engine;
pub mod metrics;
pub mod sweep;

pub use arrivals::ArrivalSpec;
pub use engine::{run_request_engine, EngineCfg, EngineResult};
pub use metrics::{summarize, RequestRecord, ServingLedger, ServingSummary};
pub use sweep::{serve_sweep, serve_sweep_to_json, ServeSweepCfg, ServeSweepRow};
