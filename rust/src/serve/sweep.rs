//! Request-serving sweep: arrival-rate points × fault arms.
//!
//! Mirrors the cluster sweep's shape: a [`ServeSweepCfg`] built from
//! [`ServeSweepCfg::full`]/[`ServeSweepCfg::quick`] (the `BENCH_QUICK=1` CI
//! smoke shape), overridable via `SERVE_*` environment variables and the
//! `serve-sweep` CLI subcommand, fanned out over
//! [`crate::util::par::parallel_map`] — every (point, arm) engine run is
//! independent and deterministic, so the sweep is bit-identical at any
//! thread count. Three arms per arrival point:
//!
//! * **healthy** — no faults; the continuous-batching baseline;
//! * **nic_down** — NIC 0 (replica 0's prefill server, rail 0) dies at 30%
//!   of the horizon: the planner reroutes around the lost rail and request
//!   latencies absorb the hit;
//! * **replica_down** — the *last* replica's server pair goes dark at 30%
//!   of the horizon (skipped at 1 replica): in-flight work replays on the
//!   survivors and the failover invariant (`lost == 0`) is asserted.
//!
//! The `serving_sweep` bench (`rust/benches/serving_sweep.rs`) prints the
//! table and writes `bench_results/serving_sweep.json`.

use crate::collectives::exec::FaultAction;
use crate::config::Preset;
use crate::fabric::FabricConfig;
use crate::scenario::ScenarioEvent;
use crate::serve::arrivals::ArrivalSpec;
use crate::serve::engine::{run_request_engine, EngineCfg};
use crate::serve::metrics::summarize;
use crate::sim::inference::InferModel;
use crate::util::par::{available_threads, parallel_map};
use crate::util::Json;

/// Sweep shape.
#[derive(Debug, Clone)]
pub struct ServeSweepCfg {
    /// Poisson arrival-rate points (requests/s). Ignored when `trace` is
    /// set.
    pub rps_points: Vec<f64>,
    /// Arrival window in seconds for the Poisson points.
    pub duration: f64,
    /// Trace-driven arrivals: explicit timestamps replacing the Poisson
    /// points (one sweep point labelled `trace`).
    pub trace: Option<Vec<f64>>,
    pub replicas: usize,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub max_batch: usize,
    pub fabric: FabricConfig,
    pub seed: u64,
    /// Worker threads for the (point × arm) fan-out; results are
    /// bit-identical at any count.
    pub threads: usize,
}

impl ServeSweepCfg {
    /// The full three-point sweep: light, moderate and saturating load.
    pub fn full() -> ServeSweepCfg {
        ServeSweepCfg {
            rps_points: vec![50.0, 200.0, 1000.0],
            duration: 2.0,
            trace: None,
            replicas: 2,
            prompt_tokens: 2000,
            output_tokens: 32,
            max_batch: 16,
            fabric: FabricConfig::ideal(),
            seed: 42,
            threads: available_threads(),
        }
    }

    /// CI smoke shape (`BENCH_QUICK=1`): the light-load point only, a
    /// shorter window and fewer output tokens.
    pub fn quick() -> ServeSweepCfg {
        ServeSweepCfg {
            rps_points: vec![50.0],
            duration: 1.0,
            output_tokens: 8,
            ..ServeSweepCfg::full()
        }
    }

    /// Override the sweep shape from `SERVE_*` environment variables:
    /// `SERVE_RPS` (comma list), `SERVE_DURATION`, `SERVE_TRACE` (comma
    /// list of timestamps), `SERVE_REPLICAS`, `SERVE_PROMPT_TOKENS`,
    /// `SERVE_OUTPUT_TOKENS`, `SERVE_MAX_BATCH`, `SERVE_FABRIC`
    /// (`flat`|`leaf-spine`), `SERVE_SEED`, `SERVE_THREADS`. Unset or
    /// unparsable variables keep the current value.
    pub fn apply_env(self) -> ServeSweepCfg {
        self.apply_overrides(|key| std::env::var(key).ok())
    }

    /// The lookup-injected core of [`Self::apply_env`] (unit-testable
    /// without mutating process environment).
    fn apply_overrides(mut self, lookup: impl Fn(&str) -> Option<String>) -> ServeSweepCfg {
        fn num<T: std::str::FromStr>(
            lookup: &impl Fn(&str) -> Option<String>,
            key: &str,
        ) -> Option<T> {
            lookup(key).and_then(|v| v.trim().parse().ok())
        }
        fn list(lookup: &impl Fn(&str) -> Option<String>, key: &str) -> Option<Vec<f64>> {
            let vals: Vec<f64> = lookup(key)?
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            (!vals.is_empty()).then_some(vals)
        }
        if let Some(v) = list(&lookup, "SERVE_RPS") {
            self.rps_points = v;
        }
        if let Some(v) = num(&lookup, "SERVE_DURATION") {
            self.duration = v;
        }
        if let Some(v) = list(&lookup, "SERVE_TRACE") {
            self.trace = Some(v);
        }
        if let Some(v) = num(&lookup, "SERVE_REPLICAS") {
            self.replicas = v;
        }
        if let Some(v) = num(&lookup, "SERVE_PROMPT_TOKENS") {
            self.prompt_tokens = v;
        }
        if let Some(v) = num(&lookup, "SERVE_OUTPUT_TOKENS") {
            self.output_tokens = v;
        }
        if let Some(v) = num(&lookup, "SERVE_MAX_BATCH") {
            self.max_batch = v;
        }
        if let Some(v) = lookup("SERVE_FABRIC") {
            if let Ok(f) = FabricConfig::from_name(v.trim()) {
                self.fabric = f;
            }
        }
        if let Some(v) = num(&lookup, "SERVE_SEED") {
            self.seed = v;
        }
        if let Some(v) = num(&lookup, "SERVE_THREADS") {
            self.threads = v;
        }
        self
    }

    /// The sweep's arrival points: `(label, rps-or-0, spec)`.
    fn points(&self) -> Vec<(String, f64, ArrivalSpec)> {
        match &self.trace {
            Some(times) => {
                vec![("trace".to_string(), 0.0, ArrivalSpec::Trace { times: times.clone() })]
            }
            None => self
                .rps_points
                .iter()
                .map(|&rps| {
                    let spec = ArrivalSpec::Poisson { rps, duration: self.duration };
                    (format!("poisson@{rps}"), rps, spec)
                })
                .collect(),
        }
    }
}

/// One (arrival point, fault arm) sweep outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSweepRow {
    pub label: String,
    /// `healthy`, `nic_down` or `replica_down`.
    pub arm: &'static str,
    /// Poisson rate of the point (0 for trace points).
    pub rps: f64,
    pub arrivals: usize,
    pub completed: usize,
    pub lost: usize,
    pub replayed: usize,
    pub rerouted: usize,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    pub goodput_tokens_per_s: f64,
    pub migrations: usize,
    pub wasted_prefill_s: f64,
}

const FAULT_FRACTION: f64 = 0.3;

/// Fail every NIC of the last replica's server pair at `at` (no restore).
fn replica_down_events(replicas: usize, nics_per_server: usize, at: f64) -> Vec<ScenarioEvent> {
    let (a, b) = (2 * (replicas - 1), 2 * (replicas - 1) + 1);
    (a * nics_per_server..(b + 1) * nics_per_server)
        .map(|nic| ScenarioEvent { at_iter: at, nic, action: FaultAction::FailNic })
        .collect()
}

/// Run the sweep: every arrival point through the healthy / nic-down /
/// replica-down arms (the last skipped at 1 replica). Panics if the
/// healthy arm drops a request or the replica-down arm violates the
/// failover invariant — with a surviving replica nothing may be lost.
pub fn serve_sweep(cfg: &ServeSweepCfg) -> Vec<ServeSweepRow> {
    let preset = Preset::simai(2 * cfg.replicas);
    let nics_per_server = preset.topo.nics_per_server;
    let mut jobs: Vec<(String, f64, &'static str, ArrivalSpec, Vec<ScenarioEvent>)> = Vec::new();
    for (label, rps, spec) in cfg.points() {
        let at = FAULT_FRACTION * spec.horizon();
        jobs.push((label.clone(), rps, "healthy", spec.clone(), vec![]));
        jobs.push((
            label.clone(),
            rps,
            "nic_down",
            spec.clone(),
            vec![ScenarioEvent { at_iter: at, nic: 0, action: FaultAction::FailNic }],
        ));
        if cfg.replicas >= 2 {
            jobs.push((
                label,
                rps,
                "replica_down",
                spec,
                replica_down_events(cfg.replicas, nics_per_server, at),
            ));
        }
    }
    let rows = parallel_map(&jobs, cfg.threads, |(label, rps, arm, spec, events)| {
        let ecfg = EngineCfg {
            model: InferModel::llama70b(),
            arrivals: spec.clone(),
            replicas: cfg.replicas,
            prompt_tokens: cfg.prompt_tokens,
            output_tokens: cfg.output_tokens,
            max_batch: cfg.max_batch,
            seed: cfg.seed,
        };
        let res = run_request_engine(&preset, &cfg.fabric, &ecfg, events, &[]);
        let s = summarize(&res, cfg.replicas);
        ServeSweepRow {
            label: label.clone(),
            arm: *arm,
            rps: *rps,
            arrivals: res.arrivals,
            completed: s.ledger.completed,
            lost: s.ledger.lost,
            replayed: s.ledger.replayed,
            rerouted: s.ledger.rerouted,
            ttft_p50: s.ttft.p50,
            ttft_p99: s.ttft.p99,
            tpot_p50: s.tpot.p50,
            tpot_p99: s.tpot.p99,
            goodput_tokens_per_s: s.goodput_tokens_per_s,
            migrations: res.migrations,
            wasted_prefill_s: s.ledger.wasted_prefill_s,
        }
    });
    for r in &rows {
        if r.arm == "healthy" {
            assert_eq!(r.lost, 0, "healthy arm dropped requests at {}", r.label);
        }
        if r.arm == "replica_down" && cfg.replicas >= 2 {
            assert_eq!(
                r.lost, 0,
                "failover invariant: {} lost requests with a surviving replica at {}",
                r.lost, r.label
            );
        }
    }
    rows
}

/// Deterministic JSON form of the sweep (the
/// `bench_results/serving_sweep.json` schema).
pub fn serve_sweep_to_json(cfg: &ServeSweepCfg, rows: &[ServeSweepRow]) -> Json {
    let mut arr = Json::arr();
    for r in rows {
        arr.push(
            Json::obj()
                .set("label", r.label.as_str())
                .set("arm", r.arm)
                .set("rps", r.rps)
                .set("arrivals", r.arrivals)
                .set("completed", r.completed)
                .set("lost", r.lost)
                .set("replayed", r.replayed)
                .set("rerouted", r.rerouted)
                .set("ttft_p50", r.ttft_p50)
                .set("ttft_p99", r.ttft_p99)
                .set("tpot_p50", r.tpot_p50)
                .set("tpot_p99", r.tpot_p99)
                .set("goodput_tokens_per_s", r.goodput_tokens_per_s)
                .set("migrations", r.migrations)
                .set("wasted_prefill_s", r.wasted_prefill_s),
        );
    }
    Json::obj()
        .set("fabric", if cfg.fabric.is_ideal() { "flat" } else { "leaf_spine" })
        .set("replicas", cfg.replicas)
        .set("prompt_tokens", cfg.prompt_tokens)
        .set("output_tokens", cfg.output_tokens)
        .set("max_batch", cfg.max_batch)
        .set("duration", cfg.duration)
        .set("seed", cfg.seed)
        .set("rows", arr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServeSweepCfg {
        ServeSweepCfg {
            rps_points: vec![40.0],
            duration: 1.0,
            output_tokens: 4,
            max_batch: 8,
            ..ServeSweepCfg::full()
        }
    }

    #[test]
    fn sweep_runs_all_arms_and_holds_the_failover_invariant() {
        let cfg = tiny();
        let rows = serve_sweep(&cfg);
        assert_eq!(rows.len(), 3, "healthy + nic_down + replica_down");
        for r in &rows {
            assert_eq!(r.completed + r.lost, r.arrivals, "{}/{}", r.label, r.arm);
            assert_eq!(r.lost, 0, "{}", r.arm);
            assert!(r.ttft_p50 > 0.0 && r.ttft_p99 >= r.ttft_p50, "{}", r.arm);
            assert!(r.goodput_tokens_per_s > 0.0, "{}", r.arm);
        }
        let healthy = rows.iter().find(|r| r.arm == "healthy").unwrap();
        let rep_down = rows.iter().find(|r| r.arm == "replica_down").unwrap();
        assert!(
            rep_down.replayed + rep_down.rerouted > 0,
            "the dying replica had work at 30% of the horizon"
        );
        assert!(rep_down.ttft_p99 >= healthy.ttft_p99, "failover can't speed requests up");
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let cfg = tiny();
        let one = serve_sweep(&ServeSweepCfg { threads: 1, ..cfg.clone() });
        let four = serve_sweep(&ServeSweepCfg { threads: 4, ..cfg });
        assert_eq!(one, four);
    }

    #[test]
    fn trace_points_replace_the_poisson_grid() {
        let cfg = ServeSweepCfg { trace: Some(vec![0.05, 0.1, 0.1, 0.4, 0.9]), ..tiny() };
        let rows = serve_sweep(&cfg);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.label == "trace" && r.rps == 0.0));
        assert_eq!(rows[0].arrivals, 5);
    }

    #[test]
    fn single_replica_skips_the_replica_down_arm() {
        let cfg = ServeSweepCfg { replicas: 1, ..tiny() };
        let rows = serve_sweep(&cfg);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.arm != "replica_down"));
    }

    #[test]
    fn env_overrides_apply_and_ignore_garbage() {
        let cfg = ServeSweepCfg::full().apply_overrides(|key| match key {
            "SERVE_RPS" => Some("25, 75".into()),
            "SERVE_REPLICAS" => Some("4".into()),
            "SERVE_FABRIC" => Some("leaf-spine".into()),
            "SERVE_MAX_BATCH" => Some("not-a-number".into()),
            _ => None,
        });
        assert_eq!(cfg.rps_points, vec![25.0, 75.0]);
        assert_eq!(cfg.replicas, 4);
        assert!(!cfg.fabric.is_ideal());
        assert_eq!(cfg.max_batch, 16, "unparsable override keeps the default");
        assert_eq!(cfg.seed, 42, "unset keys keep defaults");
    }

    #[test]
    fn json_schema_holds_every_row() {
        let cfg = tiny();
        let rows = serve_sweep(&cfg);
        let j = serve_sweep_to_json(&cfg, &rows).pretty();
        assert!(j.contains("\"rows\""));
        assert!(j.contains("\"replica_down\""));
        assert!(j.contains("\"ttft_p99\""));
        assert!(j.contains("\"goodput_tokens_per_s\""));
    }
}
