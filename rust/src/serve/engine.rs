//! The request-level serving engine: continuous batching over PD
//! server-pair replicas with replica-level failover.
//!
//! Layout: replica `r` is the prefill/decode server pair `(2r, 2r+1)`
//! ([`CommWorld::replica_pair_group`]). A request's lifecycle is
//! prefill-priority continuous batching, exactly the step loop of
//! `sim::inference::serve_sim` but with *every* cross-server transfer timed
//! through the real compiled plans:
//!
//! * **prefill** — `prompt_tokens / prefill_tps` compute, then the KV-cache
//!   shard ships prefill→decode as a `SendRecv` on the replica pair group;
//! * **decode** — one `decode_step` of compute per batch step, then the
//!   per-token TP allreduce (`2 * hidden` bytes) on the same group.
//!
//! Fault scripts from the scenario engine (times in seconds) are folded
//! into the world as simulated time passes; a step whose communication
//! window overlaps a scripted event re-runs that transfer through
//! [`CommGroup::run_scripted`], so NIC and switch faults perturb request
//! latencies mid-flight. When a replica loses its last path — every NIC of
//! a server dead, or its leaf dark — it dies: queued requests re-route (no
//! work lost), in-flight batch members replay their prefill elsewhere, and
//! the ledger counts the wasted work. Requests are dropped only while *no*
//! healthy replica exists (the failover invariant, property-tested in
//! `rust/tests/prop_serving.rs`).
//!
//! The engine is single-threaded and advances the globally-earliest action
//! (replica step or arrival; ties: step first, then lowest replica index),
//! so a run is a pure function of `(cfg, fault scripts, seed)` — corpus
//! fan-out parallelism lives a level up in `run_corpus`/`parallel_map`.

use std::collections::VecDeque;

use crate::ccl::{CommGroup, CommWorld, StrategyChoice};
use crate::collectives::exec::{FaultAction, FaultEvent};
use crate::collectives::{CollKind, PhantomPlane};
use crate::config::Preset;
use crate::fabric::{FabricConfig, SwitchAction, SwitchFaultEvent, SwitchTarget};
use crate::scenario::{ScenarioEvent, SwitchScenarioEvent};
use crate::serve::arrivals::ArrivalSpec;
use crate::serve::metrics::{RequestRecord, ServingLedger};
use crate::sim::inference::{decode_allreduce_bytes, kv_shard_bytes, InferModel};

/// Engine shape: the request-serving workload knobs.
#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub model: InferModel,
    pub arrivals: ArrivalSpec,
    pub replicas: usize,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub max_batch: usize,
    pub seed: u64,
}

/// Outcome of one engine run.
#[derive(Debug, Clone)]
pub struct EngineResult {
    /// Completed requests, sorted by request id.
    pub records: Vec<RequestRecord>,
    pub ledger: ServingLedger,
    /// Requests the arrival process generated.
    pub arrivals: usize,
    /// End of the simulation: latest of last arrival, last completion and
    /// every replica clock.
    pub total_time: f64,
    pub total_output_tokens: u64,
    /// NIC migrations across all scripted (mid-flight-perturbed) transfers.
    pub migrations: usize,
    pub retransmitted_bytes: u64,
    pub wasted_bytes: u64,
    /// Analytic payload bytes of successful transfers
    /// (`bytes_per_rank × group ranks` per step).
    pub payload_bytes: u64,
    /// True when at some point no healthy replica existed.
    pub all_down_ever: bool,
}

#[derive(Debug, Clone)]
struct Req {
    id: usize,
    arrival: f64,
    /// Earliest time a replica may start this request's prefill: the
    /// arrival, pushed forward on re-route to the re-route instant.
    ready_at: f64,
    ttft: Option<f64>,
    tokens_done: usize,
    replays: usize,
}

struct Replica {
    group: CommGroup,
    clock: f64,
    queue: VecDeque<Req>,
    batch: Vec<Req>,
    alive: bool,
    /// Nominal KV-transfer / decode-allreduce times under the world's
    /// current health epoch.
    kv_time: f64,
    ar_time: f64,
}

impl Replica {
    fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.batch.is_empty()
    }

    fn load(&self) -> usize {
        self.queue.len() + self.batch.len()
    }

    fn next_step_time(&self, max_batch: usize) -> f64 {
        if !self.queue.is_empty() && self.batch.len() < max_batch {
            self.clock.max(self.queue[0].ready_at)
        } else {
            self.clock
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Fault {
    Nic(ScenarioEvent),
    Switch(SwitchScenarioEvent),
}

impl Fault {
    fn at(&self) -> f64 {
        match self {
            Fault::Nic(e) => e.at_iter,
            Fault::Switch(e) => e.at_iter,
        }
    }
}

/// Merge the two compiled scripts (each already sorted) into one global
/// stream; NIC events win ties, matching the training runner's merge.
fn merge_faults(nic: &[ScenarioEvent], switch: &[SwitchScenarioEvent]) -> Vec<Fault> {
    let mut out = Vec::with_capacity(nic.len() + switch.len());
    let (mut ni, mut si) = (0, 0);
    while ni < nic.len() || si < switch.len() {
        let take_switch =
            ni >= nic.len() || (si < switch.len() && switch[si].at_iter < nic[ni].at_iter);
        if take_switch {
            out.push(Fault::Switch(switch[si]));
            si += 1;
        } else {
            out.push(Fault::Nic(nic[ni]));
            ni += 1;
        }
    }
    out
}

struct Engine {
    cfg: EngineCfg,
    world: CommWorld,
    replicas: Vec<Replica>,
    faults: Vec<Fault>,
    /// Next unfolded fault index.
    fi: usize,
    last_epoch: u64,
    choice: StrategyChoice,
    kv_bytes: u64,
    ar_bytes: u64,
    prefill_compute: f64,
    /// Ground-truth NIC usability (mirrors the training runner's
    /// bookkeeping) so replica liveness never requires compiling a plan
    /// over a fully-partitioned server.
    nic_up: Vec<bool>,
    leaf_up: Vec<bool>,
    records: Vec<RequestRecord>,
    ledger: ServingLedger,
    total_output_tokens: u64,
    migrations: usize,
    retransmitted_bytes: u64,
    wasted_bytes: u64,
    payload_bytes: u64,
    all_down_ever: bool,
}

impl Engine {
    /// Apply every fault at or before `t` to the world, then refresh
    /// replica liveness and nominal comm times if the health epoch moved.
    /// Faults are processed one timestamp-group at a time and the reprobe
    /// runs at the *event's* time, not the caller's ceiling: a server whose
    /// NICs all repair at 0.8 is revived at 0.8 even when the engine's next
    /// action is much later — the same reprobe path NIC repairs take, so a
    /// whole-server repair is never treated as a permanent loss.
    fn fold_until(&mut self, t: f64) {
        while self.fi < self.faults.len() && self.faults[self.fi].at() <= t {
            let at = self.faults[self.fi].at();
            while self.fi < self.faults.len() && self.faults[self.fi].at() <= at {
                match self.faults[self.fi] {
                    Fault::Nic(e) => {
                        self.world.note_failure(e.nic, e.action);
                        match e.action {
                            FaultAction::FailNic | FaultAction::CutCable => {
                                self.nic_up[e.nic] = false
                            }
                            FaultAction::Repair | FaultAction::Degrade(_) => {
                                self.nic_up[e.nic] = true
                            }
                        }
                    }
                    Fault::Switch(e) => {
                        self.world.note_switch_failure(e.target, e.action);
                        if let SwitchTarget::Leaf(l) = e.target {
                            match e.action {
                                SwitchAction::Down => self.leaf_up[l] = false,
                                SwitchAction::Up => self.leaf_up[l] = true,
                                SwitchAction::Degrade(_) => {}
                            }
                        }
                    }
                }
                self.fi += 1;
            }
            if self.world.epoch() != self.last_epoch {
                self.last_epoch = self.world.epoch();
                self.reprobe_all(at);
            }
        }
    }

    /// A replica is connected when both its servers still have a usable,
    /// leaf-connected NIC.
    fn replica_connected(&self, r: usize) -> bool {
        let topo = self.world.topo();
        let (a, b) = self.world.replica_servers(r);
        [a, b].iter().all(|&s| {
            topo.nics_of_server(s).any(|n| {
                self.nic_up[n]
                    && (topo.fabric().is_ideal() || self.leaf_up[topo.fabric().leaf_of_nic(n)])
            })
        })
    }

    fn reprobe_all(&mut self, t: f64) {
        let mut revived = Vec::new();
        for i in 0..self.replicas.len() {
            if !self.replica_connected(i) {
                self.kill_replica(i, t);
                continue;
            }
            let probe = {
                let g = &self.replicas[i].group;
                let kv = g.time_collective(CollKind::SendRecv, self.kv_bytes, self.choice);
                let ar = g.time_collective(CollKind::AllReduce, self.ar_bytes, self.choice);
                kv.zip(ar)
            };
            match probe {
                Some((kv, ar)) => {
                    let r = &mut self.replicas[i];
                    if !r.alive {
                        // Restored (e.g. replica_down with restore_after, or
                        // a whole-server repair): resumes serving from the
                        // restore instant.
                        r.alive = true;
                        r.clock = r.clock.max(t);
                        revived.push(i);
                    }
                    r.kv_time = kv;
                    r.ar_time = ar;
                }
                // Connected by ground truth but the planner found no
                // usable schedule — treat as down all the same.
                None => self.kill_replica(i, t),
            }
        }
        for i in revived {
            self.adopt_queued(i, t);
        }
    }

    /// A revived replica adopts queued (not in-flight) work from the
    /// busiest survivor, so a repair actually restores serving capacity
    /// instead of leaving the replica idle behind someone else's backlog:
    /// requests move from the back of the longest live queue while it runs
    /// more than one deeper than the revived replica's. Deterministic
    /// (longest queue, ties to the lowest index).
    fn adopt_queued(&mut self, i: usize, t: f64) {
        loop {
            let mut longest: Option<usize> = None;
            for (j, r) in self.replicas.iter().enumerate() {
                if j != i
                    && r.alive
                    && longest.is_none_or(|l| r.queue.len() > self.replicas[l].queue.len())
                {
                    longest = Some(j);
                }
            }
            let Some(j) = longest else { break };
            if self.replicas[j].queue.len() <= self.replicas[i].queue.len() + 1 {
                break;
            }
            let mut req = self.replicas[j].queue.pop_back().expect("longest queue is non-empty");
            req.ready_at = req.ready_at.max(t);
            self.ledger.rerouted += 1;
            self.replicas[i].queue.push_back(req);
        }
    }

    /// Replica `i` dies at `t`: in-flight batch members lose their prefill
    /// and decoded tokens (replayed), queued members just move (rerouted).
    fn kill_replica(&mut self, i: usize, t: f64) {
        if !self.replicas[i].alive {
            return;
        }
        let mut displaced = Vec::new();
        {
            let r = &mut self.replicas[i];
            r.alive = false;
            for mut req in r.batch.drain(..) {
                self.ledger.replayed += 1;
                self.ledger.wasted_prefill_s += self.prefill_compute;
                self.ledger.wasted_decode_tokens += req.tokens_done as u64;
                req.replays += 1;
                req.ttft = None;
                req.tokens_done = 0;
                req.ready_at = t;
                displaced.push(req);
            }
            for mut req in r.queue.drain(..) {
                self.ledger.rerouted += 1;
                req.ready_at = req.ready_at.max(t);
                displaced.push(req);
            }
        }
        if self.replicas.iter().all(|r| !r.alive) {
            self.all_down_ever = true;
        }
        for req in displaced {
            self.route(req);
        }
    }

    /// Join-shortest-queue over healthy replicas (ties: lowest index). With
    /// none alive the request is lost — `lost_while_healthy` stays zero by
    /// construction and is re-counted here as a checked invariant.
    fn route(&mut self, req: Req) {
        let mut best: Option<usize> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.alive && best.is_none_or(|b| r.load() < self.replicas[b].load()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => self.replicas[i].queue.push_back(req),
            None => {
                self.all_down_ever = true;
                self.ledger.lost += 1;
                if self.replicas.iter().any(|r| r.alive) {
                    self.ledger.lost_while_healthy += 1;
                }
            }
        }
    }

    /// Unfolded faults inside a step's communication window
    /// `(step_start, comm_end)`, rebased to the transfer clock. Events
    /// during the compute phase land at offset 0 (the transfer starts with
    /// the fault already present).
    fn pending_window(
        &self,
        step_start: f64,
        comm_start: f64,
        comm_end: f64,
    ) -> (Vec<FaultEvent>, Vec<SwitchFaultEvent>) {
        let mut nic = Vec::new();
        let mut sw = Vec::new();
        for f in &self.faults[self.fi..] {
            let at = f.at();
            if at <= step_start {
                continue;
            }
            if at >= comm_end {
                break;
            }
            let off = (at - comm_start).max(0.0);
            match f {
                Fault::Nic(e) => nic.push(FaultEvent { at: off, nic: e.nic, action: e.action }),
                Fault::Switch(e) => {
                    sw.push(SwitchFaultEvent { at: off, target: e.target, action: e.action })
                }
            }
        }
        (nic, sw)
    }

    /// Run one perturbed transfer through the executor. Returns the elapsed
    /// communication time and whether the replica crashed mid-transfer (a
    /// crash reports the nominal duration as its time-of-death proxy).
    fn scripted_comm(
        &mut self,
        i: usize,
        kind: CollKind,
        bytes: u64,
        script: Vec<FaultEvent>,
        switch_script: Vec<SwitchFaultEvent>,
        nominal: f64,
    ) -> (f64, bool) {
        let rep = self.replicas[i].group.run_scripted(
            kind,
            bytes,
            self.choice,
            script,
            switch_script,
            &mut PhantomPlane,
            0,
        );
        self.migrations += rep.migrations.len();
        for m in &rep.migrations {
            self.retransmitted_bytes += m.retransmitted_bytes;
            self.wasted_bytes += m.wasted_bytes;
        }
        match (rep.crashed, rep.completion) {
            (false, Some(c)) => (c, false),
            _ => (nominal, true),
        }
    }

    fn comm_time(
        &mut self,
        i: usize,
        kind: CollKind,
        bytes: u64,
        step_start: f64,
        comm_start: f64,
        nominal: f64,
    ) -> (f64, bool) {
        let (script, sw) = self.pending_window(step_start, comm_start, comm_start + nominal);
        if script.is_empty() && sw.is_empty() {
            (nominal, false)
        } else {
            self.scripted_comm(i, kind, bytes, script, sw, nominal)
        }
    }

    fn prefill_step(&mut self, i: usize) {
        let (s, nominal) = {
            let r = &self.replicas[i];
            (r.clock.max(r.queue[0].ready_at), r.kv_time)
        };
        let comm_start = s + self.prefill_compute;
        let (comm, crashed) =
            self.comm_time(i, CollKind::SendRecv, self.kv_bytes, s, comm_start, nominal);
        let mut req = self.replicas[i].queue.pop_front().expect("prefill pops the queue head");
        if crashed {
            let t_dead = comm_start + comm;
            self.ledger.replayed += 1;
            self.ledger.wasted_prefill_s += self.prefill_compute;
            req.replays += 1;
            req.ttft = None;
            req.tokens_done = 0;
            req.ready_at = t_dead;
            self.kill_replica(i, t_dead);
            self.route(req);
            return;
        }
        self.payload_bytes += self.kv_bytes * self.replicas[i].group.n_ranks() as u64;
        let end = comm_start + comm;
        self.replicas[i].clock = end;
        req.ttft = Some(end - req.arrival);
        req.tokens_done = 1;
        if req.tokens_done >= self.cfg.output_tokens {
            self.complete(req, end, i);
        } else {
            self.replicas[i].batch.push(req);
        }
    }

    fn decode_step(&mut self, i: usize) {
        let (s, nominal) = {
            let r = &self.replicas[i];
            (r.clock, r.ar_time)
        };
        let comm_start = s + self.cfg.model.decode_step;
        let (comm, crashed) =
            self.comm_time(i, CollKind::AllReduce, self.ar_bytes, s, comm_start, nominal);
        if crashed {
            self.kill_replica(i, comm_start + comm);
            return;
        }
        self.payload_bytes += self.ar_bytes * self.replicas[i].group.n_ranks() as u64;
        let end = comm_start + comm;
        let mut done = Vec::new();
        {
            let r = &mut self.replicas[i];
            r.clock = end;
            let mut still = Vec::new();
            for mut req in r.batch.drain(..) {
                req.tokens_done += 1;
                if req.tokens_done >= self.cfg.output_tokens {
                    done.push(req);
                } else {
                    still.push(req);
                }
            }
            r.batch = still;
        }
        for req in done {
            self.complete(req, end, i);
        }
    }

    fn complete(&mut self, req: Req, finish: f64, replica: usize) {
        self.ledger.completed += 1;
        self.total_output_tokens += req.tokens_done as u64;
        self.records.push(RequestRecord {
            id: req.id,
            arrival: req.arrival,
            ttft: req.ttft.expect("completed request has a TTFT"),
            finish,
            tokens: req.tokens_done,
            replica,
            replays: req.replays,
        });
    }

    fn step_replica(&mut self, i: usize) {
        let prefill = {
            let r = &self.replicas[i];
            !r.queue.is_empty() && r.batch.len() < self.cfg.max_batch
        };
        if prefill {
            self.prefill_step(i);
        } else {
            self.decode_step(i);
        }
    }

    fn run(mut self) -> EngineResult {
        let arrivals = self.cfg.arrivals.generate(self.cfg.seed);
        let mut ai = 0usize;
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 100_000_000, "serving engine failed to terminate");
            let mut best: Option<(f64, usize)> = None;
            for (i, r) in self.replicas.iter().enumerate() {
                if !r.alive || !r.has_work() {
                    continue;
                }
                let t = r.next_step_time(self.cfg.max_batch);
                if best.is_none_or(|(bt, _)| t < bt) {
                    best = Some((t, i));
                }
            }
            let next_arrival = arrivals.get(ai).copied();
            match (best, next_arrival) {
                // A replica step is due no later than the next arrival.
                (Some((t, i)), arr) if arr.is_none_or(|a| t <= a) => {
                    self.fold_until(t);
                    // The fold may have killed (and drained) the chosen
                    // replica; re-select on the next turn of the loop.
                    if self.replicas[i].alive && self.replicas[i].has_work() {
                        self.step_replica(i);
                    }
                }
                (_, Some(a)) => {
                    self.fold_until(a);
                    let req = Req {
                        id: ai,
                        arrival: a,
                        ready_at: a,
                        ttft: None,
                        tokens_done: 0,
                        replays: 0,
                    };
                    ai += 1;
                    self.route(req);
                }
                (None, None) => break,
            }
        }
        let mut records = self.records;
        records.sort_by_key(|r| r.id);
        let total_time = records
            .iter()
            .map(|r| r.finish)
            .chain(arrivals.last().copied())
            .chain(self.replicas.iter().map(|r| r.clock))
            .fold(0.0, f64::max);
        self.ledger.completed = records.len();
        EngineResult {
            records,
            ledger: self.ledger,
            arrivals: arrivals.len(),
            total_time,
            total_output_tokens: self.total_output_tokens,
            migrations: self.migrations,
            retransmitted_bytes: self.retransmitted_bytes,
            wasted_bytes: self.wasted_bytes,
            payload_bytes: self.payload_bytes,
            all_down_ever: self.all_down_ever,
        }
    }
}

/// Run the request engine over a fresh world built from `preset` +
/// `fabric`, driving the scenario fault scripts (times in seconds) against
/// the arrival process. Deterministic in every argument.
pub fn run_request_engine(
    preset: &Preset,
    fabric: &FabricConfig,
    cfg: &EngineCfg,
    nic_events: &[ScenarioEvent],
    switch_events: &[SwitchScenarioEvent],
) -> EngineResult {
    let channels = preset.topo.nics_per_server;
    let world = CommWorld::new_with_fabric(preset, channels, fabric);
    assert!(cfg.replicas >= 1, "need at least one replica");
    assert!(
        cfg.replicas <= world.n_serving_replicas(),
        "{} replicas need {} servers (world has {})",
        cfg.replicas,
        2 * cfg.replicas,
        world.topo().n_servers()
    );
    let kv_bytes = kv_shard_bytes(&cfg.model, cfg.prompt_tokens);
    let ar_bytes = decode_allreduce_bytes(&cfg.model);
    let choice = StrategyChoice::Auto;
    let replicas = (0..cfg.replicas)
        .map(|r| {
            let group = world.replica_pair_group(r);
            let kv = group
                .time_collective(CollKind::SendRecv, kv_bytes, choice)
                .expect("healthy replica times its KV transfer");
            let ar = group
                .time_collective(CollKind::AllReduce, ar_bytes, choice)
                .expect("healthy replica times its decode allreduce");
            Replica {
                group,
                clock: 0.0,
                queue: VecDeque::new(),
                batch: Vec::new(),
                alive: true,
                kv_time: kv,
                ar_time: ar,
            }
        })
        .collect();
    let nic_up = vec![true; world.topo().n_nics()];
    let leaf_up = vec![true; world.topo().fabric().n_leaves()];
    let last_epoch = world.epoch();
    Engine {
        cfg: cfg.clone(),
        prefill_compute: cfg.prompt_tokens as f64 / cfg.model.prefill_tps,
        world,
        replicas,
        faults: merge_faults(nic_events, switch_events),
        fi: 0,
        last_epoch,
        choice,
        kv_bytes,
        ar_bytes,
        nic_up,
        leaf_up,
        records: Vec::new(),
        ledger: ServingLedger::default(),
        total_output_tokens: 0,
        migrations: 0,
        retransmitted_bytes: 0,
        wasted_bytes: 0,
        payload_bytes: 0,
        all_down_ever: false,
    }
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::FaultAction;

    fn cfg(rps: f64, duration: f64, replicas: usize) -> EngineCfg {
        EngineCfg {
            model: InferModel::llama70b(),
            arrivals: ArrivalSpec::Poisson { rps, duration },
            replicas,
            prompt_tokens: 2000,
            output_tokens: 8,
            max_batch: 8,
            seed: 11,
        }
    }

    #[test]
    fn healthy_run_completes_every_request() {
        let preset = Preset::simai(4);
        let cfg = cfg(30.0, 1.0, 2);
        let res = run_request_engine(&preset, &FabricConfig::ideal(), &cfg, &[], &[]);
        assert!(res.arrivals > 0);
        assert_eq!(res.records.len(), res.arrivals);
        assert_eq!(res.ledger.lost, 0);
        assert_eq!(res.ledger.replayed, 0);
        assert!(!res.all_down_ever);
        assert!(res.total_output_tokens == (res.arrivals * 8) as u64);
        // TTFT at least prefill compute + KV transfer.
        let min_ttft = 2000.0 / InferModel::llama70b().prefill_tps;
        assert!(res.records.iter().all(|r| r.ttft >= min_ttft));
        // Deterministic.
        let again = run_request_engine(&preset, &FabricConfig::ideal(), &cfg, &[], &[]);
        assert_eq!(res.records, again.records);
    }

    #[test]
    fn replica_death_reroutes_without_loss() {
        let preset = Preset::simai(4);
        let topo = &preset.topo;
        let cfg = cfg(40.0, 1.5, 2);
        // Replica 1 (servers 2, 3) dies at t=0.4: every NIC fails.
        let events: Vec<ScenarioEvent> = (2 * topo.nics_per_server..4 * topo.nics_per_server)
            .map(|nic| ScenarioEvent { at_iter: 0.4, nic, action: FaultAction::FailNic })
            .collect();
        let res = run_request_engine(&preset, &FabricConfig::ideal(), &cfg, &events, &[]);
        assert_eq!(res.ledger.lost, 0, "replica 0 stays healthy");
        assert_eq!(res.ledger.lost_while_healthy, 0);
        assert_eq!(res.records.len(), res.arrivals);
        assert!(res.ledger.replayed + res.ledger.rerouted > 0, "replica 1 had work at t=0.4");
        assert!(!res.all_down_ever);
        // Everything after the death completes on replica 0.
        assert!(res.records.iter().filter(|r| r.replica == 1).all(|r| r.finish <= 0.4 + 1.0));
        assert!(res.records.iter().any(|r| r.replays > 0), "some prefills replayed");
    }

    #[test]
    fn dead_replica_is_revived_and_adopts_queued_work_after_repair() {
        let preset = Preset::simai(4);
        let topo = &preset.topo;
        // Heavy load that ends *before* the repair window closes: anything
        // the revived replica completes after t=0.8 is adopted backlog, not
        // a fresh arrival routed to it.
        let cfg = EngineCfg {
            arrivals: ArrivalSpec::Poisson { rps: 200.0, duration: 0.75 },
            ..cfg(200.0, 0.75, 2)
        };
        // Replica 1 (servers 2, 3) fully dies at 0.4 — every NIC of both
        // servers — and every NIC repairs at 0.8 (the repair window).
        let mut events: Vec<ScenarioEvent> = Vec::new();
        for nic in 2 * topo.nics_per_server..4 * topo.nics_per_server {
            events.push(ScenarioEvent { at_iter: 0.4, nic, action: FaultAction::FailNic });
            events.push(ScenarioEvent { at_iter: 0.8, nic, action: FaultAction::Repair });
        }
        events.sort_by(|a, b| a.at_iter.total_cmp(&b.at_iter).then(a.nic.cmp(&b.nic)));
        let res = run_request_engine(&preset, &FabricConfig::ideal(), &cfg, &events, &[]);
        assert_eq!(res.ledger.lost, 0, "replica 0 stays healthy: nothing may drop");
        assert_eq!(res.ledger.lost_while_healthy, 0);
        assert_eq!(res.records.len(), res.arrivals, "every request completes");
        assert!(!res.all_down_ever);
        // The regression: a fully-dead server pair must come back through
        // the repair reprobe and serve again — queued work from replica 0's
        // backlog is re-adopted after the repair window.
        assert!(
            res.records.iter().any(|r| r.replica == 1 && r.finish > 0.8),
            "repaired replica must be re-adopted into service"
        );
        assert!(res.ledger.rerouted > 0, "backlog moved to the revived replica");
    }

    #[test]
    fn total_outage_loses_requests_and_restore_resumes() {
        let preset = Preset::simai(2);
        let topo = &preset.topo;
        let cfg = EngineCfg {
            arrivals: ArrivalSpec::Poisson { rps: 30.0, duration: 2.0 },
            ..cfg(30.0, 2.0, 1)
        };
        // The only replica dies at 0.5 and is restored at 1.0.
        let mut events: Vec<ScenarioEvent> = Vec::new();
        for nic in 0..2 * topo.nics_per_server {
            events.push(ScenarioEvent { at_iter: 0.5, nic, action: FaultAction::FailNic });
            events.push(ScenarioEvent { at_iter: 1.0, nic, action: FaultAction::Repair });
        }
        events.sort_by(|a, b| a.at_iter.total_cmp(&b.at_iter).then(a.nic.cmp(&b.nic)));
        let res = run_request_engine(&preset, &FabricConfig::ideal(), &cfg, &events, &[]);
        assert!(res.all_down_ever);
        assert!(res.ledger.lost > 0, "arrivals during the outage are lost");
        assert_eq!(res.ledger.lost_while_healthy, 0);
        assert!(
            res.records.iter().any(|r| r.arrival > 1.0),
            "arrivals after the restore are served"
        );
        assert_eq!(res.records.len() + res.ledger.lost, res.arrivals);
    }
}
