//! Per-request SLO metrics and the lost/replayed-work ledger.
//!
//! The request engine produces one [`RequestRecord`] per *completed*
//! request plus a [`ServingLedger`] of everything that went wrong along the
//! way; [`summarize`] folds them into the [`ServingSummary`] that scenario
//! reports serialize — TTFT/TPOT distributions (p50/p95/p99), goodput in
//! output tokens/s, and the ledger. All JSON is deterministic, so serving
//! corpora byte-compare against golden fixtures like everything else.

use crate::serve::engine::EngineResult;
use crate::util::stats::SummaryStats;
use crate::util::{Json, Samples};

/// One completed request, absolute times in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: usize,
    pub arrival: f64,
    /// Time-to-first-token of the *final successful* stream, relative to
    /// arrival (a replayed request's clock restarts at zero work but its
    /// TTFT still counts from the original arrival).
    pub ttft: f64,
    pub finish: f64,
    /// Output tokens produced (== the workload's `output_tokens`).
    pub tokens: usize,
    /// Replica that completed the request.
    pub replica: usize,
    /// Times this request's prefill was re-run after a replica death.
    pub replays: usize,
}

impl RequestRecord {
    /// Time-per-output-token over the decode phase; `None` for single-token
    /// requests.
    pub fn tpot(&self) -> Option<f64> {
        (self.tokens > 1)
            .then(|| (self.finish - (self.arrival + self.ttft)) / (self.tokens - 1) as f64)
    }

    /// Compact array form `[id, arrival, ttft, finish, tokens, replica,
    /// replays]` — keeps golden fixtures small at hundreds of requests.
    pub fn to_json(&self) -> Json {
        let mut a = Json::arr();
        a.push(self.id);
        a.push(self.arrival);
        a.push(self.ttft);
        a.push(self.finish);
        a.push(self.tokens);
        a.push(self.replica);
        a.push(self.replays);
        a
    }
}

/// What the fault cost: requests lost/replayed/rerouted and the work thrown
/// away.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServingLedger {
    pub completed: usize,
    /// Requests dropped — only legal while *no* healthy replica exists.
    pub lost: usize,
    /// Requests whose prefill (and any decoded tokens) were discarded by a
    /// replica death and re-run elsewhere.
    pub replayed: usize,
    /// Queued-but-unstarted requests moved to another replica (no work
    /// lost).
    pub rerouted: usize,
    /// Invariant counter: requests dropped while a healthy replica existed.
    /// Structurally zero — property-tested, and a scenario report with a
    /// non-zero value fails `check_invariants`.
    pub lost_while_healthy: usize,
    /// Prefill compute seconds discarded by replica deaths.
    pub wasted_prefill_s: f64,
    /// Decoded tokens discarded by replica deaths.
    pub wasted_decode_tokens: u64,
}

impl ServingLedger {
    /// Compute seconds thrown away by replica deaths: discarded prefill
    /// plus discarded decode tokens at `decode_token_s` seconds each (the
    /// per-token share of a batch decode step). This is the lossless arm's
    /// wasted-work measure for request-serving recovery comparisons.
    pub fn wasted_compute_s(&self, decode_token_s: f64) -> f64 {
        self.wasted_prefill_s + self.wasted_decode_tokens as f64 * decode_token_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("completed", self.completed)
            .set("lost", self.lost)
            .set("replayed", self.replayed)
            .set("rerouted", self.rerouted)
            .set("lost_while_healthy", self.lost_while_healthy)
            .set("wasted_prefill_s", self.wasted_prefill_s)
            .set("wasted_decode_tokens", self.wasted_decode_tokens)
    }
}

/// The per-scenario serving outcome a [`crate::scenario::ScenarioReport`]
/// carries (and serializes) for request-serving workloads.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingSummary {
    pub replicas: usize,
    pub ttft: SummaryStats,
    pub tpot: SummaryStats,
    /// Completed output tokens per second of simulated wall clock.
    pub goodput_tokens_per_s: f64,
    pub ledger: ServingLedger,
    pub requests: Vec<RequestRecord>,
}

fn summary_json(s: &SummaryStats) -> Json {
    Json::obj()
        .set("n", s.n)
        .set("mean", s.mean)
        .set("p50", s.p50)
        .set("p95", s.p95)
        .set("p99", s.p99)
        .set("min", s.min)
        .set("max", s.max)
}

impl ServingSummary {
    pub fn to_json(&self) -> Json {
        let mut requests = Json::arr();
        for r in &self.requests {
            requests.push(r.to_json());
        }
        Json::obj()
            .set("replicas", self.replicas)
            .set("ttft", summary_json(&self.ttft))
            .set("tpot", summary_json(&self.tpot))
            .set("goodput_tokens_per_s", self.goodput_tokens_per_s)
            .set("ledger", self.ledger.to_json())
            .set("requests", requests)
    }
}

/// Fold an engine run into its SLO summary.
pub fn summarize(result: &EngineResult, replicas: usize) -> ServingSummary {
    let mut ttft = Samples::new();
    let mut tpot = Samples::new();
    for r in &result.records {
        ttft.push(r.ttft);
        if let Some(t) = r.tpot() {
            tpot.push(t);
        }
    }
    let goodput = if result.total_time > 0.0 {
        result.total_output_tokens as f64 / result.total_time
    } else {
        0.0
    };
    ServingSummary {
        replicas,
        ttft: ttft.summary(),
        tpot: tpot.summary(),
        goodput_tokens_per_s: goodput,
        ledger: result.ledger.clone(),
        requests: result.records.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpot_is_decode_time_per_token() {
        let r = RequestRecord {
            id: 0,
            arrival: 1.0,
            ttft: 0.5,
            finish: 2.5,
            tokens: 11,
            replica: 0,
            replays: 0,
        };
        // Decode span 2.5 - 1.5 = 1.0 over 10 decode tokens.
        assert!((r.tpot().unwrap() - 0.1).abs() < 1e-12);
        let single = RequestRecord { tokens: 1, ..r };
        assert_eq!(single.tpot(), None);
    }

    #[test]
    fn wasted_compute_counts_prefill_and_decode_tokens() {
        let ledger = ServingLedger {
            wasted_prefill_s: 1.5,
            wasted_decode_tokens: 200,
            ..ServingLedger::default()
        };
        assert!((ledger.wasted_compute_s(0.01) - (1.5 + 2.0)).abs() < 1e-12);
        assert_eq!(ServingLedger::default().wasted_compute_s(0.01), 0.0);
    }

    #[test]
    fn record_json_is_the_compact_array() {
        let r = RequestRecord {
            id: 3,
            arrival: 0.5,
            ttft: 0.25,
            finish: 1.0,
            tokens: 4,
            replica: 1,
            replays: 2,
        };
        assert_eq!(
            r.to_json().pretty().split_whitespace().collect::<String>(),
            "[3,0.5,0.25,1,4,1,2]"
        );
    }
}
