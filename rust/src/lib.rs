//! # R²CCL — Reliable and Resilient Collective Communication Library
//!
//! A from-scratch reproduction of *"Reliable and Resilient Collective
//! Communication Library for LLM Training and Serving"* (Wang, Yu, Xiong,
//! Liu; CS.DC 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's artifact is a NCCL plugin evaluated on multi-NIC H100/IB
//! hardware. This repository rebuilds the *entire substrate* in software
//! (see DESIGN.md §1): a flow-level RDMA fabric simulator, an NCCL-style
//! channelized collective engine with a real data plane, the paper's hot
//! repair / balance / R²-AllReduce / recursive scheduling contributions,
//! training and inference workload simulators, and the AdapCC / DéjàVu /
//! restart / reroute baselines — plus a PJRT runtime that executes real
//! JAX/Pallas-compiled transformer training steps whose gradients flow
//! through the simulated collective data plane.
//!
//! Layer map:
//! * L3 (this crate): coordination, scheduling, failure handling, simulators.
//! * L2 (`python/compile/model.py`): JAX transformer fwd/bwd → HLO text.
//! * L1 (`python/compile/kernels/`): Pallas kernels (chunk reduction, fused
//!   linear) lowered inside the L2 graph.

pub mod fabric;
pub mod netsim;
pub mod topology;
pub mod util;
pub mod config;
pub mod detect;
pub mod transport;
pub mod collectives;
pub mod schedule;
pub mod ccl;
pub mod baselines;
pub mod recovery;
pub mod scenario;
pub mod serve;
pub mod sim;
// The PJRT runtime and the end-to-end trainer need the `xla` bindings,
// which the offline build image does not provide; they are feature-gated
// so the rest of the stack (simulators, collectives, planner) builds and
// tests everywhere. Enable with `--features xla` where the crate exists.
#[cfg(feature = "xla")]
pub mod runtime;
#[cfg(feature = "xla")]
pub mod train;
pub mod bench;
