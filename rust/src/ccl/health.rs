//! Per-epoch health snapshot of the cluster.
//!
//! The seed communicator rebuilt a [`FaultPlane`] *and* a fluid-flow engine
//! from the known-failure list on every call to `plan_input`,
//! `worst_server` and `compile` — three reconstructions per collective
//! invocation, all on the per-iteration hot path of the training/serving
//! simulators. A [`HealthState`] is instead built once per *failure epoch*
//! (a monotonically increasing counter the communicator bumps on
//! `note_failure` / `clear_failures`) and shared by every consumer of the
//! current health: the planner input, the worst-server query and the
//! schedule compiler. No engine is constructed at plan time at all — the
//! snapshot only needs the NIC states, not their projection onto fluid
//! resources.

use crate::collectives::exec::FaultAction;
use crate::fabric::{SwitchAction, SwitchTarget};
use crate::netsim::{FaultPlane, NicState};
use crate::schedule::PlanInput;
use crate::topology::{NicId, Topology};

// The clamp itself lives in `netsim::fault` so the executor's fault-script
// path (which never goes through a Communicator) is protected by the same
// rule; re-exported here because the communicator's API boundary is where
// callers usually meet it.
pub use crate::netsim::{clamp_degrade_factor, MIN_DEGRADE_FACTOR};

/// Sanitize a fault action at the API boundary (currently only `Degrade`
/// carries a payload that can be malformed — values that are not positive
/// finite numbers are clamped, so the seed's `partial_cmp(..).unwrap()`
/// NaN panic in `worst_server` cannot recur).
pub fn sanitize_action(action: FaultAction) -> FaultAction {
    match action {
        FaultAction::Degrade(f) => FaultAction::Degrade(clamp_degrade_factor(f)),
        other => other,
    }
}

/// Immutable health snapshot for one failure epoch.
#[derive(Debug, Clone)]
pub struct HealthState {
    /// The failure epoch this snapshot was built for.
    pub epoch: u64,
    /// NIC-level ground truth implied by the known failures.
    pub fault_plane: FaultPlane,
    /// Remaining bandwidth fraction per server (1.0 = healthy).
    pub rem: Vec<f64>,
}

impl HealthState {
    /// Build the snapshot from the communicator's known-failure list.
    pub fn build(topo: &Topology, failures: &[(NicId, FaultAction)], epoch: u64) -> HealthState {
        HealthState::build_with_switch(topo, failures, &[], epoch)
    }

    /// Build the snapshot from NIC-level *and* switch-level known
    /// failures: a dead leaf zeroes its member NICs' remaining capacity, a
    /// degraded uplink or spine shrinks it — so `rem`, X and the α-β
    /// strategy choice all see the reduced fabric capacity. The NIC-only
    /// [`HealthState::build`] delegates here with an empty switch list.
    pub fn build_with_switch(
        topo: &Topology,
        failures: &[(NicId, FaultAction)],
        switch_failures: &[(SwitchTarget, SwitchAction)],
        epoch: u64,
    ) -> HealthState {
        let mut fault_plane = FaultPlane::new(topo);
        for &(nic, action) in failures {
            let state = match action {
                FaultAction::FailNic => NicState::NicBroken,
                FaultAction::CutCable => NicState::CableBroken,
                // note_state clamps malformed Degrade factors.
                FaultAction::Degrade(f) => NicState::Degraded(f),
                FaultAction::Repair => NicState::Healthy,
            };
            fault_plane.note_state(nic, state);
        }
        for &(target, action) in switch_failures {
            fault_plane.note_switch(topo, target, action);
        }
        let rem = (0..topo.n_servers())
            .map(|s| 1.0 - fault_plane.lost_bandwidth_fraction(topo, s))
            .collect();
        HealthState { epoch, fault_plane, rem }
    }

    /// The most degraded server and its lost-bandwidth fraction X.
    /// `total_cmp` keeps the query total even if a NaN ever slipped through
    /// (clamping at the boundary should make that impossible).
    pub fn worst_server(&self) -> (usize, f64) {
        self.rem
            .iter()
            .enumerate()
            .map(|(s, &r)| (s, 1.0 - r))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap_or((0, 0.0))
    }

    /// The most degraded server *among the given servers* (a process
    /// group's blast-radius query): the fault domain of a group collective
    /// is its own servers' NICs, not the world's. Returns the global server
    /// id and its lost-bandwidth fraction X. Over all servers in ascending
    /// order this is exactly [`HealthState::worst_server`].
    pub fn worst_server_among(&self, servers: &[usize]) -> (usize, f64) {
        servers
            .iter()
            .map(|&s| (s, 1.0 - self.rem[s]))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap_or((0, 0.0))
    }

    /// Number of servers below full bandwidth.
    pub fn degraded_servers(&self) -> usize {
        self.rem.iter().filter(|&&r| r < 1.0).count()
    }

    /// Planner input for this snapshot.
    pub fn plan_input(&self, topo: &Topology) -> PlanInput {
        PlanInput {
            n: topo.n_servers(),
            g: topo.cfg.gpus_per_server,
            server_bw: topo.cfg.nic_bw * topo.cfg.nics_per_server as f64,
            rem: self.rem.clone(),
            alpha: topo.cfg.link_latency,
        }
    }

    /// Planner input restricted to a group's servers: `n` is the group's
    /// server count, `g` its (maximum) ranks per server and `rem` the
    /// remaining-bandwidth vector of exactly those servers, so the α-β
    /// strategy choice sizes its rings — and the failure blast radius —
    /// over the group, not the world. For the world rank set this reduces
    /// to [`HealthState::plan_input`].
    pub fn plan_input_for(
        &self,
        topo: &Topology,
        servers: &[usize],
        ranks_per_server: usize,
    ) -> PlanInput {
        PlanInput {
            n: servers.len(),
            g: ranks_per_server,
            server_bw: topo.cfg.nic_bw * topo.cfg.nics_per_server as f64,
            rem: servers.iter().map(|&s| self.rem[s]).collect(),
            alpha: topo.cfg.link_latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyConfig;

    fn topo() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn build_mirrors_failures() {
        let t = topo();
        let h = HealthState::build(
            &t,
            &[(0, FaultAction::FailNic), (9, FaultAction::Degrade(0.5))],
            3,
        );
        assert_eq!(h.epoch, 3);
        assert!(!h.fault_plane.is_usable(0));
        assert!((h.rem[0] - 0.875).abs() < 1e-12);
        assert!((h.rem[1] - 0.9375).abs() < 1e-12);
        assert_eq!(h.degraded_servers(), 2);
        let (s, x) = h.worst_server();
        assert_eq!(s, 0);
        assert!((x - 0.125).abs() < 1e-12);
    }

    #[test]
    fn degrade_clamping() {
        assert_eq!(clamp_degrade_factor(f64::NAN), MIN_DEGRADE_FACTOR);
        assert_eq!(clamp_degrade_factor(-1.0), MIN_DEGRADE_FACTOR);
        assert_eq!(clamp_degrade_factor(0.0), MIN_DEGRADE_FACTOR);
        assert_eq!(clamp_degrade_factor(f64::INFINITY), 1.0);
        assert_eq!(clamp_degrade_factor(2.5), 1.0);
        assert_eq!(clamp_degrade_factor(0.25), 0.25);
    }

    #[test]
    fn nan_degrade_keeps_worst_server_total() {
        let t = topo();
        let h = HealthState::build(&t, &[(0, FaultAction::Degrade(f64::NAN))], 1);
        let (s, x) = h.worst_server();
        assert_eq!(s, 0);
        assert!(x.is_finite() && x > 0.0 && x <= 1.0, "x={x}");
        assert!(h.rem.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn group_scoped_queries_see_only_group_servers() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        // Server 0 loses a NIC; servers 1..4 healthy.
        let h = HealthState::build(&t, &[(0, FaultAction::FailNic)], 1);
        // World-scope: server 0 is the worst.
        assert_eq!(h.worst_server(), h.worst_server_among(&[0, 1, 2, 3]));
        assert_eq!(h.worst_server().0, 0);
        // A group on servers {2, 3} does not see the failure at all.
        let (s, x) = h.worst_server_among(&[2, 3]);
        assert_eq!(s, 2);
        assert_eq!(x, 0.0);
        let input = h.plan_input_for(&t, &[2, 3], 8);
        assert_eq!(input.n, 2);
        assert_eq!(input.rem, vec![1.0, 1.0]);
        assert_eq!(input.degraded_servers(), 0);
        // A group containing server 0 sees exactly its share.
        let input = h.plan_input_for(&t, &[0, 1], 4);
        assert_eq!(input.g, 4);
        assert!((input.rem[0] - 0.875).abs() < 1e-12);
        // Full-scope reduction.
        let full = h.plan_input_for(&t, &[0, 1, 2, 3], 8);
        assert_eq!(full.rem, h.plan_input(&t).rem);
    }

    #[test]
    fn switch_failures_reach_rem_and_worst_server() {
        use crate::fabric::{FabricConfig, LeafSpineCfg};
        let t = Topology::build_with_fabric(
            &TopologyConfig::simai_a100(8),
            &FabricConfig::leaf_spine_with(LeafSpineCfg {
                pod_size: 4,
                spines: 2,
                ..LeafSpineCfg::default()
            }),
        );
        let leaf = t.fabric().leaf_id(0, 0);
        let h = HealthState::build_with_switch(
            &t,
            &[],
            &[(SwitchTarget::Leaf(leaf), SwitchAction::Down)],
            1,
        );
        // Pod-0 servers each lost one of 8 NICs' fabric connectivity.
        for s in 0..4 {
            assert!((h.rem[s] - 0.875).abs() < 1e-12, "server {s}: {}", h.rem[s]);
        }
        for s in 4..8 {
            assert_eq!(h.rem[s], 1.0, "server {s}");
        }
        assert_eq!(h.degraded_servers(), 4);
        let (s, x) = h.worst_server();
        assert!(s < 4);
        assert!((x - 0.125).abs() < 1e-12);
        // An uplink degrade shrinks rem without zeroing any NIC.
        let h2 = HealthState::build_with_switch(
            &t,
            &[],
            &[(SwitchTarget::Uplink(leaf, 0), SwitchAction::Degrade(0.5))],
            2,
        );
        assert!(h2.rem[0] < 1.0 && h2.rem[0] > 0.875);
        assert!(h2.fault_plane.is_usable(0));
    }

    #[test]
    fn healthy_snapshot_is_uniform() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let h = HealthState::build(&t, &[], 0);
        assert_eq!(h.rem, vec![1.0; 4]);
        assert_eq!(h.degraded_servers(), 0);
        assert_eq!(h.worst_server().1, 0.0);
        let input = h.plan_input(&t);
        assert_eq!(input.n, 4);
        assert_eq!(input.g, 8);
    }
}
