//! The public communicator API — R²CCL's equivalent of
//! `ncclCommInitRank` + `ncclAllReduce` + transparent fault handling.
//!
//! A [`Communicator`] owns the topology, timing budgets, the health record
//! of every NIC, and the α-β planner. Each collective call compiles the
//! appropriate schedule for the *current* health state (Standard /
//! Balance / R²-AllReduce / Recursive per Table 1 + §8.4), executes it on
//! the fluid fabric, and hot-repairs any failures injected mid-operation.

use crate::collectives::exec::{
    ChannelRouting, ExecOptions, ExecReport, Executor, FaultAction, FaultEvent,
};
use crate::collectives::{
    busbw, nccl_rings, p2p, ring_all_gather, ring_allreduce, ring_broadcast,
    ring_reduce_scatter, CollKind, DataPlane, PhantomPlane,
};
use crate::config::{Preset, TimingConfig};
use crate::netsim::{self, FaultPlane};
use crate::schedule::{
    apply_balance, choose_strategy, optimal_y, r2_allreduce_schedule, recursive_allreduce,
    PlanInput, Strategy,
};
use crate::topology::{NicId, Topology};

/// Which scheduling strategy to use for a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyChoice {
    /// Let the α-β planner decide (production behaviour, §8.4).
    Auto,
    /// Force a specific strategy (used by the microbenchmarks to plot each
    /// curve of Figures 15/16).
    Force(Strategy),
    /// Hot repair only: keep NCCL's schedule and let in-flight migration
    /// handle everything (the "R²CCL-HotRepair" curve).
    HotRepairOnly,
}

/// The communicator.
pub struct Communicator {
    pub topo: Topology,
    pub timing: TimingConfig,
    pub channels: usize,
    pub opts: ExecOptions,
    /// Failures known *before* a collective starts (already detected and
    /// broadcast via OOB); the planner schedules around them.
    known_failures: Vec<(NicId, FaultAction)>,
}

impl Communicator {
    pub fn new(preset: &Preset, channels: usize) -> Self {
        Communicator {
            topo: Topology::build(&preset.topo),
            timing: preset.timing.clone(),
            channels,
            opts: ExecOptions::default(),
            known_failures: Vec::new(),
        }
    }

    pub fn with_opts(mut self, opts: ExecOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Record a failure discovered before this collective (e.g. by the
    /// periodic reprobe or a previous collective's detection).
    pub fn note_failure(&mut self, nic: NicId, action: FaultAction) {
        self.known_failures.retain(|(n, _)| *n != nic);
        if !matches!(action, FaultAction::Repair) {
            self.known_failures.push((nic, action));
        }
    }

    pub fn clear_failures(&mut self) {
        self.known_failures.clear();
    }

    pub fn known_failures(&self) -> &[(NicId, FaultAction)] {
        &self.known_failures
    }

    /// Current fault plane implied by the known failures.
    fn fault_plane(&self) -> FaultPlane {
        let mut eng = netsim::engine_for(&self.topo);
        let mut fp = FaultPlane::new(&self.topo);
        for &(nic, action) in &self.known_failures {
            match action {
                FaultAction::FailNic => fp.fail_nic(&self.topo, &mut eng, nic),
                FaultAction::CutCable => fp.cut_cable(&self.topo, &mut eng, nic),
                FaultAction::Degrade(f) => {
                    fp.set_state(&self.topo, &mut eng, nic, crate::netsim::NicState::Degraded(f))
                }
                FaultAction::Repair => fp.repair(&self.topo, &mut eng, nic),
            }
        }
        fp
    }

    /// Planner input for the current health state.
    pub fn plan_input(&self) -> PlanInput {
        let fp = self.fault_plane();
        let rem: Vec<f64> = (0..self.topo.n_servers())
            .map(|s| 1.0 - fp.lost_bandwidth_fraction(&self.topo, s))
            .collect();
        PlanInput {
            n: self.topo.n_servers(),
            g: self.topo.cfg.gpus_per_server,
            server_bw: self.topo.cfg.nic_bw * self.topo.cfg.nics_per_server as f64,
            rem,
            alpha: self.topo.cfg.link_latency,
        }
    }

    /// The most degraded server and its lost-bandwidth fraction X.
    pub fn worst_server(&self) -> (usize, f64) {
        let fp = self.fault_plane();
        (0..self.topo.n_servers())
            .map(|s| (s, fp.lost_bandwidth_fraction(&self.topo, s)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap_or((0, 0.0))
    }

    /// Compile the schedule for a collective under the current health
    /// state and chosen strategy.
    pub fn compile(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (crate::collectives::Schedule, Strategy) {
        let fp = self.fault_plane();
        let routing = ChannelRouting::default_rails(&self.topo, self.channels);
        let input = self.plan_input();
        let strategy = match choice {
            StrategyChoice::Auto => choose_strategy(kind, &input, bytes_per_rank as f64),
            StrategyChoice::Force(s) => s,
            StrategyChoice::HotRepairOnly => Strategy::Standard,
        };
        let spec = nccl_rings(&self.topo, self.channels);
        let base = match kind {
            CollKind::AllReduce => ring_allreduce(&spec, bytes_per_rank, elems),
            CollKind::ReduceScatter => ring_reduce_scatter(&spec, bytes_per_rank, elems),
            CollKind::AllGather => ring_all_gather(&spec, bytes_per_rank, elems),
            CollKind::Broadcast => ring_broadcast(&spec, bytes_per_rank, elems, 0, 8),
            CollKind::Reduce => {
                let ranks: Vec<usize> = (0..self.topo.n_gpus()).collect();
                crate::collectives::tree::tree_reduce(&ranks, bytes_per_rank, elems, 8)
            }
            CollKind::SendRecv => {
                // Default pattern: GPU i of server 0 ↔ GPU i of server 1.
                let g = self.topo.cfg.gpus_per_server;
                let pairs: Vec<(usize, usize)> =
                    (0..g).map(|i| (i, g + i)).chain((0..g).map(|i| (g + i, i))).collect();
                p2p::sendrecv(&pairs, bytes_per_rank, self.channels)
            }
            CollKind::AllToAll => {
                let ranks: Vec<usize> = (0..self.topo.n_gpus()).collect();
                p2p::all_to_all(&ranks, bytes_per_rank / self.topo.n_gpus() as u64, self.channels)
            }
        };
        let sched = match strategy {
            Strategy::Standard => {
                if matches!(choice, StrategyChoice::HotRepairOnly) {
                    base // dead-NIC traffic stays put; migration handles it
                } else if self.known_failures.is_empty() {
                    base
                } else {
                    apply_balance(&self.topo, &fp, &routing, &base)
                }
            }
            Strategy::Balance => apply_balance(&self.topo, &fp, &routing, &base),
            Strategy::R2AllReduce => {
                let (server, x) = self.worst_server();
                let y = self.pick_y(x);
                r2_allreduce_schedule(
                    &self.topo, &fp, &routing, bytes_per_rank, elems, server, y, self.channels,
                )
            }
            Strategy::Recursive => {
                recursive_allreduce(&self.topo, &fp, &routing, bytes_per_rank, elems, self.channels)
            }
        };
        (sched, strategy)
    }

    /// Y selection: Appendix-A closed form for n>2; for two-server
    /// clusters the partial "ring" is intra-node NVLink (nearly free), so a
    /// larger Y wins — the planner sweeps a small grid on the hierarchical
    /// model (§8.4's machine-specific α-β adaptation).
    pub fn pick_y(&self, x: f64) -> f64 {
        let n = self.topo.n_servers();
        let g = self.topo.cfg.gpus_per_server;
        if n > 2 {
            let y = optimal_y(n, g, x);
            if y > 0.0 {
                return y;
            }
            // Below the Appendix-A threshold the decomposition still helps
            // slightly in the fluid model thanks to duplex overlap; use a
            // conservative Y = X (the degraded server sheds exactly its
            // lost share).
            return x;
        }
        // n == 2: the partial stage runs intra-node on NVLink (nearly free)
        // and the tailored broadcast overlaps duplex-wise with the global
        // ring, so the optimum sits well above the Appendix-A serial
        // model's. Calibrated against the fluid simulation (see
        // EXPERIMENTS.md §Perf, Y-sweep): the measured argmax tracks
        // Y* ≈ 2X up to a 0.5 ceiling across X ∈ {1/8, 1/4, 1/2}.
        (2.0 * x).min(0.5)
    }

    /// Run a collective with optional mid-flight fault injections.
    pub fn run(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        let (sched, _strategy) = self.compile(kind, bytes_per_rank, elems, choice);
        let routing = ChannelRouting::default_rails(&self.topo, self.channels);
        Executor::new(&self.topo, &self.timing, routing, self.opts.clone(), script)
            .with_initial_faults(&self.known_failures)
            .run(&sched, plane)
    }

    /// Timing-only convenience: completion time of one collective.
    pub fn time_collective(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        let rep = self.run(kind, bytes_per_rank, choice, vec![], &mut PhantomPlane, 0);
        rep.completion
    }

    /// Bus bandwidth of one collective under the current health state.
    pub fn measure_busbw(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        self.time_collective(kind, bytes_per_rank, choice)
            .map(|t| busbw(kind, self.topo.n_gpus(), bytes_per_rank, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn comm() -> Communicator {
        Communicator::new(&Preset::testbed(), 8)
    }

    #[test]
    fn healthy_allreduce_uses_standard() {
        let c = comm();
        let (_s, strat) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Standard);
    }

    #[test]
    fn failure_switches_strategy() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let (_s, strat) = c.compile(CollKind::AllGather, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Balance);
        let (x_server, x) = c.worst_server();
        assert_eq!(x_server, 0);
        assert!((x - 0.125).abs() < 1e-9);
    }

    #[test]
    fn repair_clears_failure() {
        let mut c = comm();
        c.note_failure(3, FaultAction::FailNic);
        assert_eq!(c.known_failures().len(), 1);
        c.note_failure(3, FaultAction::Repair);
        assert!(c.known_failures().is_empty());
    }

    #[test]
    fn busbw_degrades_under_failure_but_less_with_balance() {
        let mut c = comm();
        let healthy = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::Auto)
            .unwrap();
        c.note_failure(0, FaultAction::FailNic);
        let balanced = c
            .measure_busbw(
                CollKind::AllReduce,
                1 << 28,
                StrategyChoice::Force(Strategy::Balance),
            )
            .unwrap();
        let hot = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::HotRepairOnly)
            .unwrap();
        assert!(balanced < healthy);
        assert!(hot < balanced, "hot {hot:.2e} should trail balance {balanced:.2e}");
        assert!(balanced / healthy > 0.8);
    }

    #[test]
    fn r2_strategy_beats_balance_large_messages() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let d = 1u64 << 29;
        let bal = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::Balance))
            .unwrap();
        let r2 = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::R2AllReduce))
            .unwrap();
        assert!(r2 > bal, "r2 {:.1}GB/s vs balance {:.1}GB/s", r2 / 1e9, bal / 1e9);
    }

    #[test]
    fn pick_y_two_servers_nonzero() {
        let c = comm();
        let y = c.pick_y(0.125);
        assert!(y > 0.0 && y < 0.9, "y={y}");
    }

    #[test]
    fn all_collectives_compile_and_run() {
        let mut c = comm();
        c.note_failure(2, FaultAction::FailNic);
        for kind in [
            CollKind::AllReduce,
            CollKind::ReduceScatter,
            CollKind::AllGather,
            CollKind::Broadcast,
            CollKind::Reduce,
            CollKind::SendRecv,
            CollKind::AllToAll,
        ] {
            let t = c.time_collective(kind, 1 << 22, StrategyChoice::Auto);
            assert!(t.is_some(), "{kind:?} failed to complete");
        }
    }
}
