//! The public communicator API — R²CCL's equivalent of
//! `ncclCommInitRank` + `ncclAllReduce` + transparent fault handling.
//!
//! A [`Communicator`] owns the topology, timing budgets, the health record
//! of every NIC, and the α-β planner. Each collective call compiles the
//! appropriate schedule for the *current* health state (Standard /
//! Balance / R²-AllReduce / Recursive per Table 1 + §8.4), executes it on
//! the fluid fabric, and hot-repairs any failures injected mid-operation.
//!
//! Plan compilation is a subsystem of its own (this module plus
//! [`health`] and [`plan_cache`]):
//! * every health mutation (`note_failure` / `clear_failures`) bumps a
//!   monotonically increasing **failure epoch**;
//! * a [`HealthState`] snapshot (fault plane + per-server remaining
//!   bandwidth) is built once per epoch and shared by `plan_input`,
//!   `worst_server` and `compile` — the seed rebuilt all of it, plus a
//!   fluid engine, on every call;
//! * compiled `(Schedule, Strategy)` pairs are memoized in a [`PlanCache`]
//!   keyed by `(kind, bytes, elems, choice, epoch, channels)`, so the
//!   per-iteration hot path of the workload simulators is one hash lookup;
//! * the [`ChannelRouting`] is built once per communicator (it depends
//!   only on the immutable topology and channel count) instead of once per
//!   compile *and* once per run.
//!
//! The compile path is scale-generic: ring/tree pipeline depths derive
//! from `gpus_per_server` and the default SendRecv pattern is a
//! ring-neighbour exchange over *all* servers, so the same communicator
//! drives the 2×8 testbed and the SimAI topologies (4–128 servers).

pub mod health;
pub mod plan_cache;

use std::cell::RefCell;
use std::sync::Arc;

use crate::collectives::exec::{
    ChannelRouting, ExecOptions, ExecReport, Executor, FaultAction, FaultEvent,
};
use crate::collectives::{
    busbw, nccl_rings, p2p, ring_all_gather, ring_allreduce, ring_broadcast,
    ring_reduce_scatter, CollKind, DataPlane, PhantomPlane, Schedule,
};
use crate::config::{Preset, TimingConfig};
use crate::schedule::{
    apply_balance, choose_strategy, optimal_y, r2_allreduce_schedule, recursive_allreduce,
    PlanInput, Strategy,
};
use crate::topology::{NicId, Topology};

pub use health::{clamp_degrade_factor, sanitize_action, HealthState, MIN_DEGRADE_FACTOR};
pub use plan_cache::{PlanCache, PlanKey, DEFAULT_PLAN_CACHE_CAPACITY};

/// Which scheduling strategy to use for a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyChoice {
    /// Let the α-β planner decide (production behaviour, §8.4).
    Auto,
    /// Force a specific strategy (used by the microbenchmarks to plot each
    /// curve of Figures 15/16).
    Force(Strategy),
    /// Hot repair only: keep NCCL's schedule and let in-flight migration
    /// handle everything (the "R²CCL-HotRepair" curve).
    HotRepairOnly,
}

/// The communicator.
///
/// `topo` is read-only after construction: the channel routing, the plan
/// cache and the health snapshot are all derived from it (and from the
/// channel count, which is private for the same reason) — rebuild the
/// communicator to change the cluster shape. `timing`/`opts` only affect
/// execution, never compiled plans, so they stay freely mutable.
pub struct Communicator {
    pub topo: Topology,
    pub timing: TimingConfig,
    channels: usize,
    pub opts: ExecOptions,
    /// Failures known *before* a collective starts (already detected and
    /// broadcast via OOB); the planner schedules around them.
    known_failures: Vec<(NicId, FaultAction)>,
    /// Failure epoch: bumped on every health mutation. Keys the health
    /// snapshot and the plan cache.
    epoch: u64,
    /// Channel↔NIC routing; immutable per communicator, built once.
    routing: ChannelRouting,
    /// Health snapshot of the current epoch (lazily built).
    health: RefCell<Option<Arc<HealthState>>>,
    /// Memoized compiled plans.
    cache: RefCell<PlanCache>,
}

impl Communicator {
    pub fn new(preset: &Preset, channels: usize) -> Self {
        let topo = Topology::build(&preset.topo);
        let routing = ChannelRouting::default_rails(&topo, channels);
        Communicator {
            topo,
            timing: preset.timing.clone(),
            channels,
            opts: ExecOptions::default(),
            known_failures: Vec::new(),
            epoch: 0,
            routing,
            health: RefCell::new(None),
            cache: RefCell::new(PlanCache::default()),
        }
    }

    pub fn with_opts(mut self, opts: ExecOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Record a failure discovered before this collective (e.g. by the
    /// periodic reprobe or a previous collective's detection). Malformed
    /// `Degrade` factors (NaN, out of range) are clamped here, at the API
    /// boundary, so no NaN ever reaches the planner or the engine.
    /// Re-reporting a standing failure is a no-op — the epoch (and with it
    /// the plan cache) only moves when the health state actually changes,
    /// so periodic reprobes don't defeat the cache.
    pub fn note_failure(&mut self, nic: NicId, action: FaultAction) {
        let action = sanitize_action(action);
        let before = self.known_failures.clone();
        self.known_failures.retain(|(n, _)| *n != nic);
        if !matches!(action, FaultAction::Repair) {
            self.known_failures.push((nic, action));
        }
        if self.known_failures != before {
            self.bump_epoch();
        }
    }

    pub fn clear_failures(&mut self) {
        if !self.known_failures.is_empty() {
            self.known_failures.clear();
            self.bump_epoch();
        }
    }

    pub fn known_failures(&self) -> &[(NicId, FaultAction)] {
        &self.known_failures
    }

    /// The current failure epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The communicator's channel↔NIC routing table.
    pub fn routing(&self) -> &ChannelRouting {
        &self.routing
    }

    /// Number of channels collectives are compiled for.
    pub fn channels(&self) -> usize {
        self.channels
    }

    fn bump_epoch(&mut self) {
        self.epoch += 1;
        *self.health.borrow_mut() = None;
    }

    /// Health snapshot of the current epoch, built at most once per epoch.
    pub fn health(&self) -> Arc<HealthState> {
        let mut slot = self.health.borrow_mut();
        if let Some(h) = slot.as_ref() {
            if h.epoch == self.epoch {
                return Arc::clone(h);
            }
        }
        let h = Arc::new(HealthState::build(&self.topo, &self.known_failures, self.epoch));
        *slot = Some(Arc::clone(&h));
        h
    }

    /// Planner input for the current health state.
    pub fn plan_input(&self) -> PlanInput {
        self.health().plan_input(&self.topo)
    }

    /// The most degraded server and its lost-bandwidth fraction X.
    pub fn worst_server(&self) -> (usize, f64) {
        self.health().worst_server()
    }

    /// Plan-cache statistics: `(hits, misses)`.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        let cache = self.cache.borrow();
        (cache.hits(), cache.misses())
    }

    /// Number of plans currently cached.
    pub fn plan_cache_len(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Compile the schedule for a collective under the current health
    /// state and chosen strategy, memoized per failure epoch. Repeated
    /// calls with identical parameters within one epoch return the same
    /// `Arc`'d schedule without recompiling.
    pub fn compile(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Arc<Schedule>, Strategy) {
        let key = PlanKey {
            kind,
            bytes_per_rank,
            elems,
            choice,
            epoch: self.epoch,
            channels: self.channels,
        };
        if let Some(hit) = self.cache.borrow_mut().get(&key) {
            return hit;
        }
        let (sched, strategy) = self.compile_uncached(kind, bytes_per_rank, elems, choice);
        let sched = Arc::new(sched);
        self.cache.borrow_mut().insert(key, Arc::clone(&sched), strategy);
        (sched, strategy)
    }

    /// Compile without consulting or filling the plan cache. This is the
    /// pure compilation path (and what the cache memoizes); the perf bench
    /// uses it to measure the seed's per-call rebuild cost.
    pub fn compile_uncached(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Schedule, Strategy) {
        let health = self.health();
        let strategy = match choice {
            StrategyChoice::Auto => {
                let input = health.plan_input(&self.topo);
                choose_strategy(kind, &input, bytes_per_rank as f64)
            }
            StrategyChoice::Force(s) => s,
            StrategyChoice::HotRepairOnly => Strategy::Standard,
        };
        let fp = &health.fault_plane;
        let sched = match strategy {
            // The base NCCL schedule is only built on the branches that use
            // it (the seed built it unconditionally, even when the R²
            // decompositions replaced it outright).
            Strategy::Standard => {
                let base = self.base_schedule(kind, bytes_per_rank, elems);
                if matches!(choice, StrategyChoice::HotRepairOnly) {
                    base // dead-NIC traffic stays put; migration handles it
                } else if self.known_failures.is_empty() {
                    base
                } else {
                    apply_balance(&self.topo, fp, &self.routing, &base)
                }
            }
            Strategy::Balance => {
                let base = self.base_schedule(kind, bytes_per_rank, elems);
                apply_balance(&self.topo, fp, &self.routing, &base)
            }
            Strategy::R2AllReduce => {
                let (server, x) = health.worst_server();
                let y = self.pick_y(x);
                r2_allreduce_schedule(
                    &self.topo,
                    fp,
                    &self.routing,
                    bytes_per_rank,
                    elems,
                    server,
                    y,
                    self.channels,
                )
            }
            Strategy::Recursive => recursive_allreduce(
                &self.topo,
                fp,
                &self.routing,
                bytes_per_rank,
                elems,
                self.channels,
            ),
        };
        (sched, strategy)
    }

    /// Chunk-pipelining depth of broadcast/tree schedules: one chunk per
    /// GPU of a server, so the intra-server NVLink chain stays saturated.
    /// (The seed hardcoded the testbed's `8`.)
    fn pipeline_depth(&self) -> usize {
        self.topo.cfg.gpus_per_server.max(1)
    }

    /// The healthy-network NCCL schedule for a collective, generic in the
    /// server count.
    fn base_schedule(&self, kind: CollKind, bytes_per_rank: u64, elems: usize) -> Schedule {
        let pipeline = self.pipeline_depth();
        match kind {
            CollKind::AllReduce => {
                let spec = nccl_rings(&self.topo, self.channels);
                ring_allreduce(&spec, bytes_per_rank, elems)
            }
            CollKind::ReduceScatter => {
                let spec = nccl_rings(&self.topo, self.channels);
                ring_reduce_scatter(&spec, bytes_per_rank, elems)
            }
            CollKind::AllGather => {
                let spec = nccl_rings(&self.topo, self.channels);
                ring_all_gather(&spec, bytes_per_rank, elems)
            }
            CollKind::Broadcast => {
                let spec = nccl_rings(&self.topo, self.channels);
                ring_broadcast(&spec, bytes_per_rank, elems, 0, pipeline)
            }
            CollKind::Reduce => {
                let ranks: Vec<usize> = (0..self.topo.n_gpus()).collect();
                crate::collectives::tree::tree_reduce(&ranks, bytes_per_rank, elems, pipeline)
            }
            CollKind::SendRecv => {
                // Default pattern: GPU i of server s ↔ GPU i of server s+1,
                // ring-wrapped over all servers.
                let pairs = p2p::ring_exchange_pairs(
                    self.topo.n_servers(),
                    self.topo.cfg.gpus_per_server,
                );
                p2p::sendrecv(&pairs, bytes_per_rank, self.channels)
            }
            CollKind::AllToAll => {
                let ranks: Vec<usize> = (0..self.topo.n_gpus()).collect();
                p2p::all_to_all(
                    &ranks,
                    bytes_per_rank / self.topo.n_gpus() as u64,
                    self.channels,
                )
            }
        }
    }

    /// Y selection: Appendix-A closed form for n>2; for two-server
    /// clusters the partial "ring" is intra-node NVLink (nearly free), so a
    /// larger Y wins — the planner sweeps a small grid on the hierarchical
    /// model (§8.4's machine-specific α-β adaptation).
    pub fn pick_y(&self, x: f64) -> f64 {
        let n = self.topo.n_servers();
        let g = self.topo.cfg.gpus_per_server;
        if n > 2 {
            let y = optimal_y(n, g, x);
            if y > 0.0 {
                return y;
            }
            // Below the Appendix-A threshold the decomposition still helps
            // slightly in the fluid model thanks to duplex overlap; use a
            // conservative Y = X (the degraded server sheds exactly its
            // lost share).
            return x;
        }
        // n == 2: the partial stage runs intra-node on NVLink (nearly free)
        // and the tailored broadcast overlaps duplex-wise with the global
        // ring, so the optimum sits well above the Appendix-A serial
        // model's. Calibrated against the fluid simulation (see
        // EXPERIMENTS.md §Perf, Y-sweep): the measured argmax tracks
        // Y* ≈ 2X up to a 0.5 ceiling across X ∈ {1/8, 1/4, 1/2}.
        (2.0 * x).min(0.5)
    }

    /// Run a collective with optional mid-flight fault injections.
    pub fn run(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        let (sched, _strategy) = self.compile(kind, bytes_per_rank, elems, choice);
        Executor::new(&self.topo, &self.timing, self.routing.clone(), self.opts.clone(), script)
            .with_initial_faults(&self.known_failures)
            .run(&sched, plane)
    }

    /// Timing-only convenience: completion time of one collective.
    pub fn time_collective(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        let rep = self.run(kind, bytes_per_rank, choice, vec![], &mut PhantomPlane, 0);
        rep.completion
    }

    /// Bus bandwidth of one collective under the current health state.
    pub fn measure_busbw(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        self.time_collective(kind, bytes_per_rank, choice)
            .map(|t| busbw(kind, self.topo.n_gpus(), bytes_per_rank, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn comm() -> Communicator {
        Communicator::new(&Preset::testbed(), 8)
    }

    #[test]
    fn healthy_allreduce_uses_standard() {
        let c = comm();
        let (_s, strat) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Standard);
    }

    #[test]
    fn failure_switches_strategy() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let (_s, strat) = c.compile(CollKind::AllGather, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Balance);
        let (x_server, x) = c.worst_server();
        assert_eq!(x_server, 0);
        assert!((x - 0.125).abs() < 1e-9);
    }

    #[test]
    fn repair_clears_failure() {
        let mut c = comm();
        c.note_failure(3, FaultAction::FailNic);
        assert_eq!(c.known_failures().len(), 1);
        c.note_failure(3, FaultAction::Repair);
        assert!(c.known_failures().is_empty());
    }

    #[test]
    fn busbw_degrades_under_failure_but_less_with_balance() {
        let mut c = comm();
        let healthy = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::Auto)
            .unwrap();
        c.note_failure(0, FaultAction::FailNic);
        let balanced = c
            .measure_busbw(
                CollKind::AllReduce,
                1 << 28,
                StrategyChoice::Force(Strategy::Balance),
            )
            .unwrap();
        let hot = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::HotRepairOnly)
            .unwrap();
        assert!(balanced < healthy);
        assert!(hot < balanced, "hot {hot:.2e} should trail balance {balanced:.2e}");
        assert!(balanced / healthy > 0.8);
    }

    #[test]
    fn r2_strategy_beats_balance_large_messages() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let d = 1u64 << 29;
        let bal = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::Balance))
            .unwrap();
        let r2 = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::R2AllReduce))
            .unwrap();
        assert!(r2 > bal, "r2 {:.1}GB/s vs balance {:.1}GB/s", r2 / 1e9, bal / 1e9);
    }

    #[test]
    fn pick_y_two_servers_nonzero() {
        let c = comm();
        let y = c.pick_y(0.125);
        assert!(y > 0.0 && y < 0.9, "y={y}");
    }

    #[test]
    fn all_collectives_compile_and_run() {
        let mut c = comm();
        c.note_failure(2, FaultAction::FailNic);
        for kind in [
            CollKind::AllReduce,
            CollKind::ReduceScatter,
            CollKind::AllGather,
            CollKind::Broadcast,
            CollKind::Reduce,
            CollKind::SendRecv,
            CollKind::AllToAll,
        ] {
            let t = c.time_collective(kind, 1 << 22, StrategyChoice::Auto);
            assert!(t.is_some(), "{kind:?} failed to complete");
        }
    }

    #[test]
    fn epoch_bumps_only_on_real_health_changes() {
        let mut c = comm();
        assert_eq!(c.epoch(), 0);
        c.note_failure(0, FaultAction::FailNic);
        assert_eq!(c.epoch(), 1);
        // Re-reporting the same standing failure (the periodic-reprobe
        // pattern) must not invalidate the plan cache.
        c.note_failure(0, FaultAction::FailNic);
        assert_eq!(c.epoch(), 1);
        c.note_failure(0, FaultAction::Repair);
        assert_eq!(c.epoch(), 2);
        // Repairing an unknown NIC / clearing an empty set are no-ops.
        c.note_failure(5, FaultAction::Repair);
        c.clear_failures();
        assert_eq!(c.epoch(), 2);
        c.note_failure(3, FaultAction::CutCable);
        assert_eq!(c.epoch(), 3);
        c.clear_failures();
        assert_eq!(c.epoch(), 4);
    }

    #[test]
    fn compile_hits_cache_within_epoch_and_misses_across() {
        let mut c = comm();
        let (s1, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (0, 1));
        let (s2, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (1, 1));
        assert!(Arc::ptr_eq(&s1, &s2), "repeat compile must return the cached plan");
        c.note_failure(0, FaultAction::FailNic);
        let (s3, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (1, 2), "epoch bump must invalidate");
        assert!(!Arc::ptr_eq(&s1, &s3));
    }

    #[test]
    fn cached_schedule_matches_uncached() {
        let mut c = comm();
        c.note_failure(1, FaultAction::FailNic);
        for choice in [
            StrategyChoice::Auto,
            StrategyChoice::HotRepairOnly,
            StrategyChoice::Force(Strategy::Balance),
            StrategyChoice::Force(Strategy::R2AllReduce),
        ] {
            let (cached, strat_c) = c.compile(CollKind::AllReduce, 1 << 22, 0, choice);
            let (fresh, strat_f) = c.compile_uncached(CollKind::AllReduce, 1 << 22, 0, choice);
            assert_eq!(strat_c, strat_f);
            assert_eq!(*cached, fresh, "{choice:?}: cached and fresh plans differ");
        }
    }

    #[test]
    fn nan_degrade_is_clamped_at_the_boundary() {
        // Regression: the seed's worst_server used partial_cmp().unwrap()
        // and panicked when a Degrade carried NaN.
        let mut c = comm();
        c.note_failure(0, FaultAction::Degrade(f64::NAN));
        let (server, x) = c.worst_server();
        assert_eq!(server, 0);
        assert!(x.is_finite() && x > 0.0 && x < 1.0, "x={x}");
        assert!(c.plan_input().rem.iter().all(|r| r.is_finite()));
        match c.known_failures()[0].1 {
            FaultAction::Degrade(f) => assert_eq!(f, MIN_DEGRADE_FACTOR),
            other => panic!("expected clamped Degrade, got {other:?}"),
        }
        // The collective still compiles and completes (in simulated time).
        let t = c.time_collective(CollKind::AllGather, 1 << 12, StrategyChoice::Auto);
        assert!(t.is_some());
    }

    #[test]
    fn sendrecv_wraps_around_all_servers() {
        let c = Communicator::new(&Preset::simai(4), 2);
        let (sched, _) = c.compile(CollKind::SendRecv, 1 << 16, 0, StrategyChoice::Auto);
        sched.validate().unwrap();
        // Every adjacent server pair is exercised, including 3 -> 0.
        let g = c.topo.cfg.gpus_per_server;
        for s in 0..4usize {
            let d = (s + 1) % 4;
            assert!(
                sched.groups.iter().any(|grp| grp
                    .subs
                    .iter()
                    .any(|t| t.src / g == s && t.dst / g == d)),
                "missing server edge {s} -> {d}"
            );
        }
    }

    #[test]
    fn pipeline_depth_follows_gpus_per_server() {
        // Broadcast chunking = channels × (N-1) edges × pipeline chunks,
        // with pipeline == gpus_per_server (4 here, not the testbed's 8).
        let mut cfg = Preset::simai(2);
        cfg.topo.gpus_per_server = 4;
        cfg.topo.nics_per_server = 4;
        let c = Communicator::new(&cfg, 2);
        let (sched, _) = c.compile(CollKind::Broadcast, 1 << 16, 0, StrategyChoice::Auto);
        let n = c.topo.n_gpus();
        assert_eq!(sched.len(), 2 * (n - 1) * 4);
    }
}
