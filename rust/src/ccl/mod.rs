//! The public communicator API — R²CCL's equivalent of
//! `ncclCommInitRank` + `ncclAllReduce` + transparent fault handling,
//! redesigned around process groups.
//!
//! * [`CommWorld`] owns the topology, timing budgets, channel↔NIC routing,
//!   the health record of every NIC (with its monotonic failure epoch) and
//!   the shared [`PlanCache`].
//! * [`CommGroup`] — created via [`CommWorld::group`] or the
//!   [`ParallelLayout`] helpers (`tp_groups` / `pp_pairs` / `dp_groups`) —
//!   exposes `compile / run / time_collective / measure_busbw` scoped to a
//!   rank subset: exactly how TP/PP/DP traffic runs on real clusters, where
//!   each collective has its own NCCL communicator but all share the NICs
//!   and the fault domain.
//!
//! Plan compilation remains a subsystem of its own ([`health`] +
//! [`plan_cache`]):
//! * every health mutation (`note_failure` / `clear_failures`) bumps a
//!   monotonically increasing **failure epoch**;
//! * a [`HealthState`] snapshot (fault plane + per-server remaining
//!   bandwidth) is built once per epoch and shared by every group's
//!   `plan_input`, `worst_server` and `compile`;
//! * compiled `(Schedule, Strategy)` pairs are memoized in the world's
//!   [`PlanCache`] keyed by `(group, kind, bytes, elems, choice, epoch,
//!   channels)`, so the per-iteration hot path of the workload simulators
//!   is one hash lookup per group collective;
//! * the [`ChannelRouting`] is built once per world and shared by `Arc`
//!   with every executor run — group schedules read only the rows of their
//!   member servers.
//!
//! [`Communicator`] survives as a deprecated thin alias over the world
//! group for one release; new code should build a [`CommWorld`] and issue
//! collectives on groups.
//!
//! [`ChannelRouting`]: crate::collectives::exec::ChannelRouting

pub mod group;
pub mod health;
pub mod plan_cache;

use std::sync::Arc;

use crate::collectives::exec::{
    ChannelRouting, ExecOptions, ExecReport, Executor, FaultAction, FaultEvent,
};
use crate::collectives::{CollKind, DataPlane, PhantomPlane, Schedule};
use crate::config::{Preset, TimingConfig};
use crate::schedule::{PlanInput, Strategy};
use crate::topology::{NicId, Topology};

pub use group::{CommGroup, CommWorld, ElasticKind, ElasticTransition, ParallelLayout};
pub use health::{clamp_degrade_factor, sanitize_action, HealthState, MIN_DEGRADE_FACTOR};
pub use plan_cache::{PlanCache, PlanKey, DEFAULT_PLAN_CACHE_CAPACITY};

/// Which scheduling strategy to use for a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyChoice {
    /// Let the α-β planner decide (production behaviour, §8.4).
    Auto,
    /// Force a specific strategy (used by the microbenchmarks to plot each
    /// curve of Figures 15/16).
    Force(Strategy),
    /// Hot repair only: keep NCCL's schedule and let in-flight migration
    /// handle everything (the "R²CCL-HotRepair" curve).
    HotRepairOnly,
}

/// The legacy world-scope communicator: a thin wrapper over
/// [`CommWorld`] + its world [`CommGroup`], kept for one release so
/// existing callers compile. Every call delegates to the world group, so
/// behaviour (including plan-cache hits and epochs) is identical to
/// `CommWorld::world_group()`.
#[deprecated(
    since = "0.2.0",
    note = "use CommWorld + CommGroup (world.group(..) / world.world_group())"
)]
pub struct Communicator {
    /// Read-only topology (kept as a public field for API compatibility;
    /// the authoritative copy lives in the world).
    pub topo: Topology,
    pub timing: TimingConfig,
    pub opts: ExecOptions,
    world: CommWorld,
    group: CommGroup,
    /// Mirror of the world's failure list, so `known_failures` can keep
    /// returning a slice.
    failures: Vec<(NicId, FaultAction)>,
}

#[allow(deprecated)]
impl Communicator {
    pub fn new(preset: &Preset, channels: usize) -> Self {
        let world = CommWorld::new(preset, channels);
        let group = world.world_group();
        Communicator {
            topo: world.topo().clone(),
            timing: preset.timing.clone(),
            opts: ExecOptions::default(),
            world,
            group,
            failures: Vec::new(),
        }
    }

    pub fn with_opts(mut self, opts: ExecOptions) -> Self {
        self.world.set_opts(opts.clone());
        self.opts = opts;
        self
    }

    /// The underlying world (migration path to the new API).
    pub fn world(&self) -> &CommWorld {
        &self.world
    }

    /// The world-scope group this alias delegates to.
    pub fn world_group(&self) -> &CommGroup {
        &self.group
    }

    /// Record a failure discovered before this collective; see
    /// [`CommWorld::note_failure`] for the semantics (sanitization, epoch
    /// bumping, reprobe-friendly dedup).
    pub fn note_failure(&mut self, nic: NicId, action: FaultAction) {
        self.world.note_failure(nic, action);
        self.failures = self.world.known_failures();
    }

    pub fn clear_failures(&mut self) {
        self.world.clear_failures();
        self.failures.clear();
    }

    pub fn known_failures(&self) -> &[(NicId, FaultAction)] {
        &self.failures
    }

    /// The current failure epoch.
    pub fn epoch(&self) -> u64 {
        self.world.epoch()
    }

    /// The communicator's channel↔NIC routing table.
    pub fn routing(&self) -> &ChannelRouting {
        self.world.routing()
    }

    /// Number of channels collectives are compiled for.
    pub fn channels(&self) -> usize {
        self.world.channels()
    }

    /// Health snapshot of the current epoch, built at most once per epoch.
    pub fn health(&self) -> Arc<HealthState> {
        self.world.health()
    }

    /// Planner input for the current health state.
    pub fn plan_input(&self) -> PlanInput {
        self.world.plan_input()
    }

    /// The most degraded server and its lost-bandwidth fraction X.
    pub fn worst_server(&self) -> (usize, f64) {
        self.world.worst_server()
    }

    /// Plan-cache statistics: `(hits, misses)`.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        self.world.plan_cache_stats()
    }

    /// Number of plans currently cached.
    pub fn plan_cache_len(&self) -> usize {
        self.world.plan_cache_len()
    }

    /// Compile the schedule for a world-scope collective; see
    /// [`CommGroup::compile`].
    pub fn compile(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Arc<Schedule>, Strategy) {
        self.group.compile(kind, bytes_per_rank, elems, choice)
    }

    /// Compile without consulting or filling the plan cache.
    pub fn compile_uncached(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Schedule, Strategy) {
        self.group.compile_uncached(kind, bytes_per_rank, elems, choice)
    }

    /// Y selection for the world's shape; see [`CommGroup::pick_y`].
    pub fn pick_y(&self, x: f64) -> f64 {
        self.group.pick_y(x)
    }

    /// Run a collective with optional mid-flight fault injections. Honors
    /// the (public, mutable) `timing` and `opts` fields for compatibility,
    /// and mirrors `opts` into the world so a subsequent
    /// `world_group().run(..)` executes with the same options.
    pub fn run(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        self.world.set_opts(self.opts.clone());
        let (sched, _strategy) = self.compile(kind, bytes_per_rank, elems, choice);
        Executor::new(&self.topo, &self.timing, self.world.routing_arc(), self.opts.clone(), script)
            .with_initial_faults(&self.failures)
            .run(&sched, plane)
    }

    /// Timing-only convenience: completion time of one collective.
    pub fn time_collective(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        let rep = self.run(kind, bytes_per_rank, choice, vec![], &mut PhantomPlane, 0);
        rep.completion
    }

    /// Bus bandwidth of one collective under the current health state.
    pub fn measure_busbw(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        self.time_collective(kind, bytes_per_rank, choice)
            .map(|t| crate::collectives::busbw(kind, self.topo.n_gpus(), bytes_per_rank, t))
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn comm() -> Communicator {
        Communicator::new(&Preset::testbed(), 8)
    }

    #[test]
    fn healthy_allreduce_uses_standard() {
        let c = comm();
        let (_s, strat) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Standard);
    }

    #[test]
    fn failure_switches_strategy() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let (_s, strat) = c.compile(CollKind::AllGather, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Balance);
        let (x_server, x) = c.worst_server();
        assert_eq!(x_server, 0);
        assert!((x - 0.125).abs() < 1e-9);
    }

    #[test]
    fn repair_clears_failure() {
        let mut c = comm();
        c.note_failure(3, FaultAction::FailNic);
        assert_eq!(c.known_failures().len(), 1);
        c.note_failure(3, FaultAction::Repair);
        assert!(c.known_failures().is_empty());
    }

    #[test]
    fn busbw_degrades_under_failure_but_less_with_balance() {
        let mut c = comm();
        let healthy = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::Auto)
            .unwrap();
        c.note_failure(0, FaultAction::FailNic);
        let balanced = c
            .measure_busbw(
                CollKind::AllReduce,
                1 << 28,
                StrategyChoice::Force(Strategy::Balance),
            )
            .unwrap();
        let hot = c
            .measure_busbw(CollKind::AllReduce, 1 << 28, StrategyChoice::HotRepairOnly)
            .unwrap();
        assert!(balanced < healthy);
        assert!(hot < balanced, "hot {hot:.2e} should trail balance {balanced:.2e}");
        assert!(balanced / healthy > 0.8);
    }

    #[test]
    fn r2_strategy_beats_balance_large_messages() {
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let d = 1u64 << 29;
        let bal = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::Balance))
            .unwrap();
        let r2 = c
            .measure_busbw(CollKind::AllReduce, d, StrategyChoice::Force(Strategy::R2AllReduce))
            .unwrap();
        assert!(r2 > bal, "r2 {:.1}GB/s vs balance {:.1}GB/s", r2 / 1e9, bal / 1e9);
    }

    #[test]
    fn pick_y_two_servers_nonzero() {
        let c = comm();
        let y = c.pick_y(0.125);
        assert!(y > 0.0 && y < 0.9, "y={y}");
    }

    #[test]
    fn all_collectives_compile_and_run() {
        let mut c = comm();
        c.note_failure(2, FaultAction::FailNic);
        for kind in [
            CollKind::AllReduce,
            CollKind::ReduceScatter,
            CollKind::AllGather,
            CollKind::Broadcast,
            CollKind::Reduce,
            CollKind::SendRecv,
            CollKind::AllToAll,
        ] {
            let t = c.time_collective(kind, 1 << 22, StrategyChoice::Auto);
            assert!(t.is_some(), "{kind:?} failed to complete");
        }
    }

    #[test]
    fn epoch_bumps_only_on_real_health_changes() {
        let mut c = comm();
        assert_eq!(c.epoch(), 0);
        c.note_failure(0, FaultAction::FailNic);
        assert_eq!(c.epoch(), 1);
        // Re-reporting the same standing failure (the periodic-reprobe
        // pattern) must not invalidate the plan cache.
        c.note_failure(0, FaultAction::FailNic);
        assert_eq!(c.epoch(), 1);
        c.note_failure(0, FaultAction::Repair);
        assert_eq!(c.epoch(), 2);
        // Repairing an unknown NIC / clearing an empty set are no-ops.
        c.note_failure(5, FaultAction::Repair);
        c.clear_failures();
        assert_eq!(c.epoch(), 2);
        c.note_failure(3, FaultAction::CutCable);
        assert_eq!(c.epoch(), 3);
        c.clear_failures();
        assert_eq!(c.epoch(), 4);
    }

    #[test]
    fn compile_hits_cache_within_epoch_and_misses_across() {
        let mut c = comm();
        let (s1, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (0, 1));
        let (s2, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (1, 1));
        assert!(Arc::ptr_eq(&s1, &s2), "repeat compile must return the cached plan");
        c.note_failure(0, FaultAction::FailNic);
        let (s3, _) = c.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(c.plan_cache_stats(), (1, 2), "epoch bump must invalidate");
        assert!(!Arc::ptr_eq(&s1, &s3));
    }

    #[test]
    fn cached_schedule_matches_uncached() {
        let mut c = comm();
        c.note_failure(1, FaultAction::FailNic);
        for choice in [
            StrategyChoice::Auto,
            StrategyChoice::HotRepairOnly,
            StrategyChoice::Force(Strategy::Balance),
            StrategyChoice::Force(Strategy::R2AllReduce),
        ] {
            let (cached, strat_c) = c.compile(CollKind::AllReduce, 1 << 22, 0, choice);
            let (fresh, strat_f) = c.compile_uncached(CollKind::AllReduce, 1 << 22, 0, choice);
            assert_eq!(strat_c, strat_f);
            assert_eq!(*cached, fresh, "{choice:?}: cached and fresh plans differ");
        }
    }

    #[test]
    fn nan_degrade_is_clamped_at_the_boundary() {
        // Regression: the seed's worst_server used partial_cmp().unwrap()
        // and panicked when a Degrade carried NaN.
        let mut c = comm();
        c.note_failure(0, FaultAction::Degrade(f64::NAN));
        let (server, x) = c.worst_server();
        assert_eq!(server, 0);
        assert!(x.is_finite() && x > 0.0 && x < 1.0, "x={x}");
        assert!(c.plan_input().rem.iter().all(|r| r.is_finite()));
        match c.known_failures()[0].1 {
            FaultAction::Degrade(f) => assert_eq!(f, MIN_DEGRADE_FACTOR),
            other => panic!("expected clamped Degrade, got {other:?}"),
        }
        // The collective still compiles and completes (in simulated time).
        let t = c.time_collective(CollKind::AllGather, 1 << 12, StrategyChoice::Auto);
        assert!(t.is_some());
    }

    #[test]
    fn sendrecv_wraps_around_all_servers() {
        let c = Communicator::new(&Preset::simai(4), 2);
        let (sched, _) = c.compile(CollKind::SendRecv, 1 << 16, 0, StrategyChoice::Auto);
        sched.validate().unwrap();
        // Every adjacent server pair is exercised, including 3 -> 0.
        let g = c.topo.cfg.gpus_per_server;
        for s in 0..4usize {
            let d = (s + 1) % 4;
            assert!(
                sched.groups.iter().any(|grp| grp
                    .subs
                    .iter()
                    .any(|t| t.src / g == s && t.dst / g == d)),
                "missing server edge {s} -> {d}"
            );
        }
    }

    #[test]
    fn pipeline_depth_follows_gpus_per_server() {
        // Broadcast chunking = channels × (N-1) edges × pipeline chunks,
        // with pipeline == gpus_per_server (4 here, not the testbed's 8).
        let mut cfg = Preset::simai(2);
        cfg.topo.gpus_per_server = 4;
        cfg.topo.nics_per_server = 4;
        let c = Communicator::new(&cfg, 2);
        let (sched, _) = c.compile(CollKind::Broadcast, 1 << 16, 0, StrategyChoice::Auto);
        let n = c.topo.n_gpus();
        assert_eq!(sched.len(), 2 * (n - 1) * 4);
    }

    #[test]
    fn alias_matches_world_group_bit_for_bit() {
        // The deprecated alias must stay a *thin* wrapper: same plans, same
        // strategies, same cache (its compile delegates to the world group).
        let mut c = comm();
        c.note_failure(0, FaultAction::FailNic);
        let (via_alias, s1) = c.compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
        let (via_group, s2) =
            c.world_group().compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(s1, s2);
        assert!(Arc::ptr_eq(&via_alias, &via_group), "alias must share the cached plan");
    }
}
