//! Process-group communicators: [`CommWorld`] + [`CommGroup`].
//!
//! Real 3D-parallel workloads never run one world-scope communicator. A
//! Megatron TP8/PP2 layout drives tensor-parallel AllReduce on intra-server
//! groups, pipeline SendRecv on stage pairs, and data-parallel AllReduce on
//! replica groups — each collective runs over a *subset* of ranks on its
//! own NCCL-style communicator, while every group shares the same NICs,
//! failure epoch and fault domain.
//!
//! The split mirrors that:
//! * [`CommWorld`] owns everything global and shared: the topology, the
//!   channel↔NIC routing table, the known-failure list with its monotonic
//!   failure epoch, the per-epoch [`HealthState`] snapshot, and one
//!   [`PlanCache`] keyed by `(group, kind, bytes, elems, choice, epoch,
//!   channels)`.
//! * [`CommGroup`] is a cheap handle (an `Rc` of the world's shared state
//!   plus an interned rank set) exposing the familiar `compile` / `run` /
//!   `time_collective` / `measure_busbw` surface scoped to its ranks: rings
//!   walk only member GPUs, SendRecv pairs only member servers, the α-β
//!   planner's X and `worst_server` are computed over the group's servers
//!   only, and the R²/recursive decompositions peel *group* servers.
//!
//! Group identity is the rank *set*: two `world.group(..)` calls over the
//! same ranks intern to the same id and share cached plans. The world group
//! (`world.world_group()`) compiles bit-identical schedules to the legacy
//! world-scope `Communicator` (property-tested in
//! `rust/tests/prop_groups.rs`).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::collectives::exec::{
    ChannelRouting, ExecOptions, ExecReport, Executor, FaultAction, FaultEvent, ObserveOptions,
};
use crate::collectives::{
    busbw, p2p, ring_all_gather, ring_allreduce, ring_broadcast, ring_reduce_scatter,
    rings_for_ranks, CollKind, DataPlane, PhantomPlane, Schedule,
};
use crate::config::{Preset, TimingConfig};
use crate::fabric::{FabricConfig, SwitchAction, SwitchFaultEvent, SwitchTarget};
use crate::schedule::{
    apply_balance, choose_strategy, optimal_y, r2_allreduce_schedule_for, recursive_allreduce_for,
    PlanInput, Strategy,
};
use crate::topology::{GpuId, NicId, RankSet, ServerId, Topology};

use super::health::HealthState;
use super::plan_cache::{PlanCache, PlanKey};
use super::StrategyChoice;

/// Kind of one elastic membership transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElasticKind {
    /// Servers left the active membership (whole-server loss).
    Shrink,
    /// Servers (re)joined the active membership (repair / scale-up).
    Expand,
    /// A registered spare replaced a dead active server in one transition.
    Promote,
}

impl ElasticKind {
    pub fn label(&self) -> &'static str {
        match self {
            ElasticKind::Shrink => "shrink",
            ElasticKind::Expand => "expand",
            ElasticKind::Promote => "promote",
        }
    }
}

/// Record of one elastic membership transition. Each transition bumps the
/// failure epoch exactly once — `epoch` is the world epoch *after* the
/// transition, so the plan cache is invalidated exactly once per
/// membership change regardless of how many servers move.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ElasticTransition {
    pub kind: ElasticKind,
    /// Servers that moved. For `Promote` this is `[dead, spare]`.
    pub servers: Vec<ServerId>,
    /// World failure epoch after the transition.
    pub epoch: u64,
    /// Active-server count after the transition.
    pub active_after: usize,
}

/// Elastic membership state: which servers are currently active (own
/// ranks in elastic layouts), which inactive servers are registered as
/// promotable spares, and the log of every transition so far.
struct MembershipState {
    active: Vec<bool>,
    spares: Vec<ServerId>,
    log: Vec<ElasticTransition>,
}

/// A 3D parallelism layout over a world of `tp × dp × pp` ranks, mapped to
/// GPUs in Megatron's default order: tensor-parallel innermost (contiguous
/// ranks — intra-server for tp ≤ gpus_per_server), then data-parallel, then
/// pipeline stages outermost. Rank ids equal global GPU ids; the layout
/// must exactly fill the world it is used with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelLayout {
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
}

impl ParallelLayout {
    pub fn new(tp: usize, dp: usize, pp: usize) -> ParallelLayout {
        assert!(tp >= 1 && dp >= 1 && pp >= 1, "parallel degrees must be >= 1");
        ParallelLayout { tp, dp, pp }
    }

    pub fn n_ranks(&self) -> usize {
        self.tp * self.dp * self.pp
    }

    /// Global rank of coordinate (tp_i, dp_i, pp_i).
    pub fn rank(&self, tp_i: usize, dp_i: usize, pp_i: usize) -> usize {
        debug_assert!(tp_i < self.tp && dp_i < self.dp && pp_i < self.pp);
        (pp_i * self.dp + dp_i) * self.tp + tp_i
    }

    /// Tensor-parallel groups: one per (pp, dp) coordinate, `tp` ranks each.
    pub fn tp_ranks(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.pp * self.dp);
        for pp_i in 0..self.pp {
            for dp_i in 0..self.dp {
                out.push((0..self.tp).map(|t| self.rank(t, dp_i, pp_i)).collect());
            }
        }
        out
    }

    /// Data-parallel (replica) groups: one per (pp, tp) coordinate, `dp`
    /// ranks each.
    pub fn dp_ranks(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.pp * self.tp);
        for pp_i in 0..self.pp {
            for tp_i in 0..self.tp {
                out.push((0..self.dp).map(|d| self.rank(tp_i, d, pp_i)).collect());
            }
        }
        out
    }

    /// Pipeline stage-pair groups: one per consecutive stage boundary,
    /// containing *both* stages' ranks — the communicator a PP boundary
    /// SendRecv runs on (all per-rank activations transfers of the boundary
    /// move concurrently and contend for the same NICs).
    pub fn pp_pair_ranks(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.pp.saturating_sub(1));
        for pp_i in 0..self.pp.saturating_sub(1) {
            let mut ranks = Vec::with_capacity(2 * self.tp * self.dp);
            for dp_i in 0..self.dp {
                for t in 0..self.tp {
                    ranks.push(self.rank(t, dp_i, pp_i));
                    ranks.push(self.rank(t, dp_i, pp_i + 1));
                }
            }
            out.push(ranks);
        }
        out
    }
}

/// World-global state shared by the world handle and every group handle.
struct WorldShared {
    topo: Topology,
    timing: TimingConfig,
    channels: usize,
    routing: Arc<ChannelRouting>,
    opts: RefCell<ExecOptions>,
    /// Failures known *before* a collective starts (already detected and
    /// broadcast via OOB); the planner schedules around them.
    failures: RefCell<Vec<(NicId, FaultAction)>>,
    /// Standing switch-scoped failures (leaf/spine fabrics): dead leaves,
    /// degraded spines/uplinks. Same epoch discipline as NIC failures.
    switch_failures: RefCell<Vec<(SwitchTarget, SwitchAction)>>,
    /// Failure epoch: bumped on every health mutation. Keys the health
    /// snapshot and the plan cache.
    epoch: Cell<u64>,
    /// Health snapshot of the current epoch (lazily built).
    health: RefCell<Option<Arc<HealthState>>>,
    /// Memoized compiled plans, shared by every group.
    cache: RefCell<PlanCache>,
    /// Interned rank sets → group id (group identity is the rank set).
    group_ids: RefCell<HashMap<Vec<GpuId>, u64>>,
    /// Elastic membership: active servers, registered spares, transition
    /// log. All servers are active at construction.
    membership: RefCell<MembershipState>,
}

impl WorldShared {
    fn bump_epoch(&self) {
        self.epoch.set(self.epoch.get() + 1);
        *self.health.borrow_mut() = None;
    }

    fn health(&self) -> Arc<HealthState> {
        let mut slot = self.health.borrow_mut();
        if let Some(h) = slot.as_ref() {
            if h.epoch == self.epoch.get() {
                return Arc::clone(h);
            }
        }
        let h = Arc::new(HealthState::build_with_switch(
            &self.topo,
            &self.failures.borrow(),
            &self.switch_failures.borrow(),
            self.epoch.get(),
        ));
        *slot = Some(Arc::clone(&h));
        h
    }
}

/// The world communicator: owns the topology, channel routing, failure
/// epoch, health snapshot and plan cache. Collectives are issued through
/// [`CommGroup`] handles created with [`CommWorld::group`] (or the layout
/// helpers); [`CommWorld::world_group`] covers every rank for world-scope
/// calls.
pub struct CommWorld {
    shared: Rc<WorldShared>,
}

impl CommWorld {
    pub fn new(preset: &Preset, channels: usize) -> CommWorld {
        CommWorld::new_with_fabric(preset, channels, &FabricConfig::ideal())
    }

    /// Build a world over an explicit inter-server fabric.
    /// `FabricConfig::ideal()` reproduces [`CommWorld::new`] bit-for-bit; a
    /// leaf/spine fabric adds the switch tier to every engine this world's
    /// executors run on and makes switch-scoped failures
    /// ([`CommWorld::note_switch_failure`]) expressible.
    pub fn new_with_fabric(
        preset: &Preset,
        channels: usize,
        fabric: &FabricConfig,
    ) -> CommWorld {
        let topo = Topology::build_with_fabric(&preset.topo, fabric);
        let routing = Arc::new(ChannelRouting::default_rails(&topo, channels));
        let membership = MembershipState {
            active: vec![true; topo.n_servers()],
            spares: Vec::new(),
            log: Vec::new(),
        };
        CommWorld {
            shared: Rc::new(WorldShared {
                topo,
                timing: preset.timing.clone(),
                channels,
                routing,
                opts: RefCell::new(ExecOptions::default()),
                failures: RefCell::new(Vec::new()),
                switch_failures: RefCell::new(Vec::new()),
                epoch: Cell::new(0),
                health: RefCell::new(None),
                cache: RefCell::new(PlanCache::default()),
                group_ids: RefCell::new(HashMap::new()),
                membership: RefCell::new(membership),
            }),
        }
    }

    pub fn with_opts(self, opts: ExecOptions) -> CommWorld {
        *self.shared.opts.borrow_mut() = opts;
        self
    }

    pub fn set_opts(&self, opts: ExecOptions) {
        *self.shared.opts.borrow_mut() = opts;
    }

    pub fn opts(&self) -> ExecOptions {
        self.shared.opts.borrow().clone()
    }

    pub fn topo(&self) -> &Topology {
        &self.shared.topo
    }

    pub fn timing(&self) -> &TimingConfig {
        &self.shared.timing
    }

    /// Number of channels collectives are compiled for.
    pub fn channels(&self) -> usize {
        self.shared.channels
    }

    /// The world's channel↔NIC routing table (shared by `Arc` with every
    /// executor run — groups read only the rows of their member servers).
    pub fn routing(&self) -> &ChannelRouting {
        &self.shared.routing
    }

    pub(crate) fn routing_arc(&self) -> Arc<ChannelRouting> {
        Arc::clone(&self.shared.routing)
    }

    /// Record a failure discovered before the next collective (e.g. by the
    /// periodic reprobe or a previous collective's detection). Malformed
    /// `Degrade` factors (NaN, out of range) are clamped here, at the API
    /// boundary, so no NaN ever reaches the planner or the engine.
    /// Re-reporting a standing failure is a no-op — the epoch (and with it
    /// the plan cache) only moves when the health state actually changes,
    /// so periodic reprobes don't defeat the cache.
    pub fn note_failure(&mut self, nic: NicId, action: FaultAction) {
        let action = super::health::sanitize_action(action);
        let mut failures = self.shared.failures.borrow_mut();
        let before = failures.clone();
        failures.retain(|(n, _)| *n != nic);
        if !matches!(action, FaultAction::Repair) {
            failures.push((nic, action));
        }
        let changed = *failures != before;
        drop(failures);
        if changed {
            self.shared.bump_epoch();
        }
    }

    pub fn clear_failures(&mut self) {
        let any = !self.shared.failures.borrow().is_empty()
            || !self.shared.switch_failures.borrow().is_empty();
        if any {
            self.shared.failures.borrow_mut().clear();
            self.shared.switch_failures.borrow_mut().clear();
            self.shared.bump_epoch();
        }
    }

    pub fn known_failures(&self) -> Vec<(NicId, FaultAction)> {
        self.shared.failures.borrow().clone()
    }

    /// Record a switch-scoped failure (dead leaf, degraded spine/uplink)
    /// known before the next collective. Requires a leaf/spine fabric.
    /// `Up` clears the target's standing entry; re-reporting an identical
    /// state is a no-op, so the epoch (and the plan cache) only moves when
    /// the fabric health actually changes.
    pub fn note_switch_failure(&mut self, target: SwitchTarget, action: SwitchAction) {
        assert!(
            !self.shared.topo.fabric().is_ideal(),
            "note_switch_failure needs a leaf/spine fabric (world is flat)"
        );
        assert!(
            !matches!((target, action), (SwitchTarget::Spine(_), SwitchAction::Down)),
            "spine outages are unsupported: NIC-level migration cannot re-pin ECMP around \
             a dead spine — express spine trouble as SwitchAction::Degrade"
        );
        let action = match action {
            SwitchAction::Degrade(f) => {
                SwitchAction::Degrade(crate::netsim::clamp_degrade_factor(f))
            }
            other => other,
        };
        let mut failures = self.shared.switch_failures.borrow_mut();
        let before = failures.clone();
        failures.retain(|(t, _)| *t != target);
        let clears = matches!(action, SwitchAction::Up)
            || matches!(action, SwitchAction::Degrade(f) if f >= 1.0);
        if !clears {
            failures.push((target, action));
        }
        let changed = *failures != before;
        drop(failures);
        if changed {
            self.shared.bump_epoch();
        }
    }

    pub fn known_switch_failures(&self) -> Vec<(SwitchTarget, SwitchAction)> {
        self.shared.switch_failures.borrow().clone()
    }

    /// The current failure epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.get()
    }

    /// Health snapshot of the current epoch, built at most once per epoch.
    pub fn health(&self) -> Arc<HealthState> {
        self.shared.health()
    }

    /// World-scope planner input.
    pub fn plan_input(&self) -> PlanInput {
        self.health().plan_input(&self.shared.topo)
    }

    /// The most degraded server and its lost-bandwidth fraction X,
    /// world-scope.
    pub fn worst_server(&self) -> (usize, f64) {
        self.health().worst_server()
    }

    /// Plan-cache statistics: `(hits, misses)` across all groups.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        let cache = self.shared.cache.borrow();
        (cache.hits(), cache.misses())
    }

    /// Number of plans currently cached across all groups.
    pub fn plan_cache_len(&self) -> usize {
        self.shared.cache.borrow().len()
    }

    /// Create (or re-open) the communicator group over `ranks`. Ranks must
    /// be unique, in range and non-empty; order does not matter — group
    /// identity is the rank *set*, and re-opening the same set yields the
    /// same group id (and therefore the same cached plans).
    pub fn group(&self, ranks: &[GpuId]) -> CommGroup {
        let set = RankSet::new(&self.shared.topo, ranks);
        let mut ids = self.shared.group_ids.borrow_mut();
        let next = ids.len() as u64;
        let id = *ids.entry(set.ranks().to_vec()).or_insert(next);
        CommGroup { shared: Rc::clone(&self.shared), set: Arc::new(set), id }
    }

    /// The group covering every rank of the world.
    pub fn world_group(&self) -> CommGroup {
        let ranks: Vec<GpuId> = (0..self.shared.topo.n_gpus()).collect();
        self.group(&ranks)
    }

    fn check_layout(&self, layout: &ParallelLayout) {
        assert_eq!(
            layout.n_ranks(),
            self.shared.topo.n_gpus(),
            "parallel layout must exactly fill the world"
        );
    }

    /// Tensor-parallel groups of a layout (one per (pp, dp) coordinate).
    pub fn tp_groups(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_layout(layout);
        layout.tp_ranks().iter().map(|r| self.group(r)).collect()
    }

    /// Data-parallel replica groups of a layout (one per (pp, tp)
    /// coordinate).
    pub fn dp_groups(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_layout(layout);
        layout.dp_ranks().iter().map(|r| self.group(r)).collect()
    }

    /// Pipeline stage-pair groups of a layout (one per stage boundary,
    /// spanning both stages — the communicator PP SendRecv runs on). Also
    /// the prefill→decode pair of a disaggregated serving instance.
    pub fn pp_pairs(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_layout(layout);
        layout.pp_pair_ranks().iter().map(|r| self.group(r)).collect()
    }

    /// Number of disaggregated serving replicas this world can host:
    /// replica `r` is the prefill/decode server pair `(2r, 2r+1)`, so a
    /// world holds `n_servers / 2` replicas (an odd trailing server is a
    /// spare and hosts none).
    pub fn n_serving_replicas(&self) -> usize {
        self.shared.topo.n_servers() / 2
    }

    /// The `(prefill, decode)` server ids of serving replica `r`.
    pub fn replica_servers(&self, r: usize) -> (usize, usize) {
        assert!(r < self.n_serving_replicas(), "replica {r} out of range");
        (2 * r, 2 * r + 1)
    }

    /// Every rank of serving replica `r` (both servers of the pair, in
    /// rank order).
    pub fn replica_ranks(&self, r: usize) -> Vec<GpuId> {
        let (p, d) = self.replica_servers(r);
        let g = self.shared.topo.cfg.gpus_per_server;
        (p * g..(d + 1) * g).collect()
    }

    /// The communicator group of serving replica `r`: its PD KV SendRecv
    /// and its decode-step TP allreduce both run on this pair group. On
    /// the 2-server testbed this is exactly the group `pd_kv_pair` opens,
    /// so plans (and plan-cache entries) are shared.
    pub fn replica_pair_group(&self, r: usize) -> CommGroup {
        self.group(&self.replica_ranks(r))
    }

    // ---- elastic membership -------------------------------------------

    /// Hold `spares` out of the active membership at bootstrap and register
    /// them as promotable spares (in the given order). Elastic layouts then
    /// fill only the remaining active servers; [`CommWorld::promote_spare`]
    /// swaps a spare in for a dead server later without changing the world
    /// size. Not logged as a transition — it is initial-state setup, but it
    /// does bump the epoch (once) when it changes the membership.
    pub fn set_spares(&mut self, spares: &[ServerId]) {
        if spares.is_empty() {
            return;
        }
        let n = self.shared.topo.n_servers();
        {
            let mut m = self.shared.membership.borrow_mut();
            for &s in spares {
                assert!(s < n, "spare server {s} out of range (n_servers {n})");
                assert!(m.active[s], "server {s} is already inactive or a duplicate spare");
                m.active[s] = false;
                m.spares.push(s);
            }
            assert!(
                m.active.iter().any(|&a| a),
                "cannot hold every server out as a spare"
            );
        }
        self.shared.bump_epoch();
    }

    /// Shrink the active membership around `dead_servers`: the surviving
    /// GPUs are re-ranked (see [`CommWorld::active_ranks`]) and every
    /// elastic layout group rebuilt afterwards excludes the dead servers.
    /// The failure epoch — and with it the plan cache — is bumped exactly
    /// once for the whole transition, however many servers die together.
    pub fn shrink(&mut self, dead_servers: &[ServerId]) -> Result<ElasticTransition, String> {
        if dead_servers.is_empty() {
            return Err("shrink of zero servers".into());
        }
        let n = self.shared.topo.n_servers();
        let tr = {
            let mut m = self.shared.membership.borrow_mut();
            let mut seen = Vec::new();
            for &s in dead_servers {
                if s >= n {
                    return Err(format!("server {s} out of range (n_servers {n})"));
                }
                if !m.active[s] {
                    return Err(format!("server {s} is not active"));
                }
                if seen.contains(&s) {
                    return Err(format!("server {s} listed twice"));
                }
                seen.push(s);
            }
            if m.active.iter().filter(|&&a| a).count() == seen.len() {
                return Err("shrink would leave no active server".into());
            }
            for &s in &seen {
                m.active[s] = false;
            }
            let mut servers = seen;
            servers.sort_unstable();
            ElasticTransition {
                kind: ElasticKind::Shrink,
                servers,
                epoch: self.shared.epoch.get() + 1,
                active_after: m.active.iter().filter(|&&a| a).count(),
            }
        };
        self.shared.bump_epoch();
        self.shared.membership.borrow_mut().log.push(tr.clone());
        Ok(tr)
    }

    /// Expand the active membership with `new_servers` (currently inactive
    /// servers: repaired ones, or registered spares — which are then
    /// unregistered). Same exactly-one-epoch-bump discipline as `shrink`.
    pub fn expand(&mut self, new_servers: &[ServerId]) -> Result<ElasticTransition, String> {
        if new_servers.is_empty() {
            return Err("expand of zero servers".into());
        }
        let n = self.shared.topo.n_servers();
        let tr = {
            let mut m = self.shared.membership.borrow_mut();
            let mut seen = Vec::new();
            for &s in new_servers {
                if s >= n {
                    return Err(format!("server {s} out of range (n_servers {n})"));
                }
                if m.active[s] {
                    return Err(format!("server {s} is already active"));
                }
                if seen.contains(&s) {
                    return Err(format!("server {s} listed twice"));
                }
                seen.push(s);
            }
            for &s in &seen {
                m.active[s] = true;
                m.spares.retain(|&sp| sp != s);
            }
            let mut servers = seen;
            servers.sort_unstable();
            ElasticTransition {
                kind: ElasticKind::Expand,
                servers,
                epoch: self.shared.epoch.get() + 1,
                active_after: m.active.iter().filter(|&&a| a).count(),
            }
        };
        self.shared.bump_epoch();
        self.shared.membership.borrow_mut().log.push(tr.clone());
        Ok(tr)
    }

    /// Promote the first registered spare in place of dead active server
    /// `dead`: one transition, one epoch bump, world size unchanged. The
    /// transition's `servers` field is `[dead, spare]`.
    pub fn promote_spare(&mut self, dead: ServerId) -> Result<ElasticTransition, String> {
        let n = self.shared.topo.n_servers();
        let tr = {
            let mut m = self.shared.membership.borrow_mut();
            if dead >= n {
                return Err(format!("server {dead} out of range (n_servers {n})"));
            }
            if !m.active[dead] {
                return Err(format!("server {dead} is not active"));
            }
            if m.spares.is_empty() {
                return Err("no spare server registered".into());
            }
            let spare = m.spares.remove(0);
            m.active[dead] = false;
            m.active[spare] = true;
            ElasticTransition {
                kind: ElasticKind::Promote,
                servers: vec![dead, spare],
                epoch: self.shared.epoch.get() + 1,
                active_after: m.active.iter().filter(|&&a| a).count(),
            }
        };
        self.shared.bump_epoch();
        self.shared.membership.borrow_mut().log.push(tr.clone());
        Ok(tr)
    }

    /// Active servers, ascending.
    pub fn active_servers(&self) -> Vec<ServerId> {
        let m = self.shared.membership.borrow();
        (0..self.shared.topo.n_servers()).filter(|&s| m.active[s]).collect()
    }

    pub fn n_active_servers(&self) -> usize {
        self.shared.membership.borrow().active.iter().filter(|&&a| a).count()
    }

    pub fn is_active(&self, server: ServerId) -> bool {
        let m = self.shared.membership.borrow();
        server < m.active.len() && m.active[server]
    }

    /// Registered spare servers in promotion order.
    pub fn spare_servers(&self) -> Vec<ServerId> {
        self.shared.membership.borrow().spares.clone()
    }

    /// The elastic transition log (shrinks, expands, promotions) since
    /// construction, in order.
    pub fn elastic_log(&self) -> Vec<ElasticTransition> {
        self.shared.membership.borrow().log.clone()
    }

    /// The surviving-GPU re-ranking: elastic rank `i` maps to global GPU
    /// `active_ranks()[i]`. Active servers contribute their GPUs in global
    /// order, so with every server active this is the identity map and
    /// elastic layout groups equal the plain layout groups bit-for-bit.
    pub fn active_ranks(&self) -> Vec<GpuId> {
        let g = self.shared.topo.cfg.gpus_per_server;
        let m = self.shared.membership.borrow();
        let mut out = Vec::new();
        for s in 0..self.shared.topo.n_servers() {
            if m.active[s] {
                out.extend(s * g..(s + 1) * g);
            }
        }
        out
    }

    pub fn n_active_ranks(&self) -> usize {
        self.n_active_servers() * self.shared.topo.cfg.gpus_per_server
    }

    /// The group covering every rank of the active membership.
    pub fn active_group(&self) -> CommGroup {
        self.group(&self.active_ranks())
    }

    fn check_elastic_layout(&self, layout: &ParallelLayout) {
        assert_eq!(
            layout.n_ranks(),
            self.n_active_ranks(),
            "parallel layout must exactly fill the active membership"
        );
    }

    fn remap_elastic(&self, sets: Vec<Vec<usize>>) -> Vec<CommGroup> {
        let act = self.active_ranks();
        sets.into_iter()
            .map(|ranks| {
                let mapped: Vec<GpuId> = ranks.into_iter().map(|r| act[r]).collect();
                self.group(&mapped)
            })
            .collect()
    }

    /// Tensor-parallel groups of a layout over the *active* membership:
    /// layout ranks are mapped through the surviving-GPU re-ranking.
    pub fn tp_groups_elastic(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_elastic_layout(layout);
        self.remap_elastic(layout.tp_ranks())
    }

    /// Data-parallel replica groups over the active membership (DP-shrink:
    /// after a shrink, rebuild with `dp` reduced so the layout fills the
    /// surviving ranks — replicas are redistributed, not restarted).
    pub fn dp_groups_elastic(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_elastic_layout(layout);
        self.remap_elastic(layout.dp_ranks())
    }

    /// Pipeline stage-pair groups over the active membership.
    pub fn pp_pairs_elastic(&self, layout: &ParallelLayout) -> Vec<CommGroup> {
        self.check_elastic_layout(layout);
        self.remap_elastic(layout.pp_pair_ranks())
    }
}

/// A communicator group: the `compile / run / time_collective /
/// measure_busbw` surface scoped to a rank subset. Cheap to clone and to
/// re-create; all heavyweight state lives in the shared world.
#[derive(Clone)]
pub struct CommGroup {
    shared: Rc<WorldShared>,
    set: Arc<RankSet>,
    id: u64,
}

impl CommGroup {
    /// The world-interned group id (part of the plan-cache key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Member ranks, sorted ascending.
    pub fn ranks(&self) -> &[GpuId] {
        self.set.ranks()
    }

    pub fn n_ranks(&self) -> usize {
        self.set.len()
    }

    /// Servers hosting member ranks — the group's fault domain.
    pub fn servers(&self) -> &[ServerId] {
        self.set.servers()
    }

    /// The group's rank set.
    pub fn rank_set(&self) -> &RankSet {
        &self.set
    }

    /// Group-scoped planner input: `n` is the group's server count, `rem`
    /// the remaining bandwidth of exactly those servers.
    pub fn plan_input(&self) -> PlanInput {
        self.shared.health().plan_input_for(
            &self.shared.topo,
            self.set.servers(),
            self.set.max_ranks_per_server(),
        )
    }

    /// The most degraded *group* server (global id) and its lost-bandwidth
    /// fraction X. Failures outside the group's servers are invisible here
    /// — that is the point of rank-scoped communicators.
    pub fn worst_server(&self) -> (ServerId, f64) {
        self.shared.health().worst_server_among(self.set.servers())
    }

    /// Y selection for the group's shape: Appendix-A closed form for n>2
    /// group servers; the calibrated 2X rule for two-server groups (see
    /// `Communicator::pick_y` history); 0 for single-server groups (their
    /// collectives ride NVLink — there is no NIC ring to decompose).
    pub fn pick_y(&self, x: f64) -> f64 {
        let n = self.set.n_servers();
        let g = self.set.max_ranks_per_server();
        if n < 2 {
            return 0.0;
        }
        if n > 2 {
            let y = optimal_y(n, g, x);
            if y > 0.0 {
                return y;
            }
            // Below the Appendix-A threshold the decomposition still helps
            // slightly in the fluid model thanks to duplex overlap; use a
            // conservative Y = X (the degraded server sheds exactly its
            // lost share).
            return x;
        }
        // n == 2: the partial stage runs intra-node on NVLink (nearly free)
        // and the tailored broadcast overlaps duplex-wise with the global
        // ring; calibrated against the fluid simulation, the measured
        // argmax tracks Y* ≈ 2X up to a 0.5 ceiling.
        (2.0 * x).min(0.5)
    }

    /// Compile the group's schedule for a collective under the current
    /// health state, memoized per failure epoch in the world's shared plan
    /// cache. Repeated calls with identical parameters within one epoch
    /// return the same `Arc`'d schedule without recompiling.
    pub fn compile(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Arc<Schedule>, Strategy) {
        let key = PlanKey {
            group: self.id,
            kind,
            bytes_per_rank,
            elems,
            choice,
            epoch: self.shared.epoch.get(),
            channels: self.shared.channels,
        };
        if let Some(hit) = self.shared.cache.borrow_mut().get(&key) {
            return hit;
        }
        let (sched, strategy) = self.compile_uncached(kind, bytes_per_rank, elems, choice);
        let sched = Arc::new(sched);
        self.shared.cache.borrow_mut().insert(key, Arc::clone(&sched), strategy);
        (sched, strategy)
    }

    /// Compile without consulting or filling the plan cache (the pure
    /// compilation path the cache memoizes).
    pub fn compile_uncached(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        elems: usize,
        choice: StrategyChoice,
    ) -> (Schedule, Strategy) {
        let shared = &self.shared;
        let topo = &shared.topo;
        let health = shared.health();
        let strategy = match choice {
            StrategyChoice::Auto => {
                if self.set.n_servers() < 2 {
                    // Single-server groups ride NVLink; NIC health cannot
                    // change their schedule.
                    Strategy::Standard
                } else {
                    let input = health.plan_input_for(
                        topo,
                        self.set.servers(),
                        self.set.max_ranks_per_server(),
                    );
                    choose_strategy(kind, &input, bytes_per_rank as f64)
                }
            }
            StrategyChoice::Force(s) => s,
            StrategyChoice::HotRepairOnly => Strategy::Standard,
        };
        let fp = &health.fault_plane;
        // A failure is relevant only when it degrades a *group* server —
        // the blast radius of rank-scoped collectives.
        let group_degraded =
            self.set.servers().iter().any(|&s| health.rem[s] < 1.0);
        let routing = &shared.routing;
        let channels = shared.channels;
        let sched = match strategy {
            Strategy::Standard => {
                let base = self.base_schedule(kind, bytes_per_rank, elems);
                if matches!(choice, StrategyChoice::HotRepairOnly) {
                    base // dead-NIC traffic stays put; migration handles it
                } else if !group_degraded {
                    base
                } else {
                    apply_balance(topo, fp, routing, &base)
                }
            }
            Strategy::Balance => {
                let base = self.base_schedule(kind, bytes_per_rank, elems);
                apply_balance(topo, fp, routing, &base)
            }
            Strategy::R2AllReduce => {
                let (server, x) = health.worst_server_among(self.set.servers());
                let y = self.pick_y(x);
                r2_allreduce_schedule_for(
                    topo,
                    fp,
                    routing,
                    bytes_per_rank,
                    elems,
                    server,
                    y,
                    channels,
                    &self.set,
                )
            }
            Strategy::Recursive => recursive_allreduce_for(
                topo,
                fp,
                routing,
                bytes_per_rank,
                elems,
                channels,
                &self.set,
            ),
        };
        (sched, strategy)
    }

    /// The healthy-network NCCL schedule for a collective over the group's
    /// ranks. Pipeline depths derive from the group's densest server, the
    /// SendRecv default pattern is a ring-neighbour exchange over the
    /// *group's* servers.
    fn base_schedule(&self, kind: CollKind, bytes_per_rank: u64, elems: usize) -> Schedule {
        let channels = self.shared.channels;
        let pipeline = self.set.max_ranks_per_server().max(1);
        match kind {
            CollKind::AllReduce => {
                let spec = rings_for_ranks(&self.set, channels);
                ring_allreduce(&spec, bytes_per_rank, elems)
            }
            CollKind::ReduceScatter => {
                let spec = rings_for_ranks(&self.set, channels);
                ring_reduce_scatter(&spec, bytes_per_rank, elems)
            }
            CollKind::AllGather => {
                let spec = rings_for_ranks(&self.set, channels);
                ring_all_gather(&spec, bytes_per_rank, elems)
            }
            CollKind::Broadcast => {
                let spec = rings_for_ranks(&self.set, channels);
                ring_broadcast(&spec, bytes_per_rank, elems, 0, pipeline)
            }
            CollKind::Reduce => crate::collectives::tree::tree_reduce(
                self.set.ranks(),
                bytes_per_rank,
                elems,
                pipeline,
            ),
            CollKind::SendRecv => {
                let pairs = p2p::ring_exchange_pairs_for(&self.set);
                p2p::sendrecv(&pairs, bytes_per_rank, channels)
            }
            CollKind::AllToAll => p2p::all_to_all(
                self.set.ranks(),
                bytes_per_rank / self.set.len() as u64,
                channels,
            ),
        }
    }

    /// Run a group collective with optional mid-flight fault injections.
    pub fn run(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        self.run_scripted(kind, bytes_per_rank, choice, script, Vec::new(), plane, elems)
    }

    /// Run a group collective with NIC-level *and* switch-level mid-flight
    /// fault scripts. Standing switch failures (a dead leaf the world
    /// already knows about) are applied as initial executor state before
    /// the NIC faults, so NIC failover choices see the shrunken fabric.
    #[allow(clippy::too_many_arguments)]
    pub fn run_scripted(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        switch_script: Vec<SwitchFaultEvent>,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        self.run_observed(
            kind,
            bytes_per_rank,
            choice,
            script,
            switch_script,
            ObserveOptions::default(),
            plane,
            elems,
        )
    }

    /// Run a group collective with crisp fault scripts *plus* the
    /// observability layer: a gray-fault script, standing gray state from
    /// earlier iterations, and optional per-collective telemetry
    /// collection. With a default [`ObserveOptions`] this is exactly
    /// [`CommGroup::run_scripted`].
    #[allow(clippy::too_many_arguments)]
    pub fn run_observed(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
        script: Vec<FaultEvent>,
        switch_script: Vec<SwitchFaultEvent>,
        observe: ObserveOptions,
        plane: &mut dyn DataPlane,
        elems: usize,
    ) -> ExecReport {
        let (sched, _strategy) = self.compile(kind, bytes_per_rank, elems, choice);
        let shared = &self.shared;
        let mut exec = Executor::new(
            &shared.topo,
            &shared.timing,
            Arc::clone(&shared.routing),
            shared.opts.borrow().clone(),
            script,
        )
        .with_switch_script(switch_script)
        .with_initial_switch_faults(&shared.switch_failures.borrow())
        .with_initial_faults(&shared.failures.borrow());
        if !observe.gray_script.is_empty() || observe.gray_seed != 0 {
            exec = exec.with_gray_script(observe.gray_script, observe.gray_seed);
        }
        if !observe.standing_gray.is_empty() {
            exec = exec.with_initial_gray(&observe.standing_gray);
        }
        if observe.telemetry {
            exec = exec.with_telemetry();
        }
        exec.run(&sched, plane)
    }

    /// Timing-only convenience: completion time of one group collective.
    pub fn time_collective(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        let rep = self.run(kind, bytes_per_rank, choice, vec![], &mut PhantomPlane, 0);
        rep.completion
    }

    /// Bus bandwidth of one group collective under the current health
    /// state, normalized to the *group's* rank count.
    pub fn measure_busbw(
        &self,
        kind: CollKind,
        bytes_per_rank: u64,
        choice: StrategyChoice,
    ) -> Option<f64> {
        self.time_collective(kind, bytes_per_rank, choice)
            .map(|t| busbw(kind, self.set.len(), bytes_per_rank, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::RealPlane;

    fn world() -> CommWorld {
        CommWorld::new(&Preset::testbed(), 8)
    }

    #[test]
    fn layout_tp8_pp2_maps_to_servers() {
        let layout = ParallelLayout::new(8, 1, 2);
        assert_eq!(layout.n_ranks(), 16);
        let tp = layout.tp_ranks();
        assert_eq!(tp, vec![(0..8).collect::<Vec<_>>(), (8..16).collect::<Vec<_>>()]);
        let pairs = layout.pp_pair_ranks();
        assert_eq!(pairs.len(), 1);
        let mut p = pairs[0].clone();
        p.sort_unstable();
        assert_eq!(p, (0..16).collect::<Vec<_>>());
        // DP=1: replica groups are singletons.
        assert!(layout.dp_ranks().iter().all(|g| g.len() == 1));
    }

    #[test]
    fn layout_dp16_is_one_replica_group() {
        let layout = ParallelLayout::new(1, 16, 1);
        let dp = layout.dp_ranks();
        assert_eq!(dp.len(), 1);
        assert_eq!(dp[0], (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn layout_mixed_coordinates_are_disjoint_and_cover() {
        let layout = ParallelLayout::new(4, 2, 2);
        for groups in [layout.tp_ranks(), layout.dp_ranks()] {
            let mut all: Vec<usize> = groups.concat();
            all.sort_unstable();
            assert_eq!(all, (0..16).collect::<Vec<_>>(), "groups must partition the world");
        }
        // Stage-pair groups cover both stages.
        let pairs = layout.pp_pair_ranks();
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].len(), 16);
    }

    #[test]
    fn group_ids_intern_by_rank_set() {
        let w = world();
        let a = w.group(&[0, 1, 2]);
        let b = w.group(&[2, 0, 1]); // order irrelevant
        let c = w.group(&[0, 1, 3]);
        assert_eq!(a.id(), b.id());
        assert_ne!(a.id(), c.id());
        assert_eq!(w.world_group().id(), w.world_group().id());
    }

    #[test]
    fn groups_share_the_plan_cache_with_distinct_keys() {
        let w = world();
        let g0 = w.group(&(0..8).collect::<Vec<_>>());
        let g1 = w.group(&(8..16).collect::<Vec<_>>());
        let (s0, _) = g0.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        let (s1, _) = g1.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert_eq!(w.plan_cache_stats(), (0, 2), "distinct groups must not collide");
        assert!(!Arc::ptr_eq(&s0, &s1));
        let (s0b, _) = g0.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(Arc::ptr_eq(&s0, &s0b));
        assert_eq!(w.plan_cache_stats(), (1, 2));
        // Re-opening the same rank set hits the same entries.
        let g0_again = w.group(&(0..8).collect::<Vec<_>>());
        let (s0c, _) = g0_again.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(Arc::ptr_eq(&s0, &s0c));
    }

    #[test]
    fn tp_group_schedules_stay_intra_server() {
        let w = world();
        let layout = ParallelLayout::new(8, 1, 2);
        for (i, g) in w.tp_groups(&layout).iter().enumerate() {
            let (sched, strat) = g.compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
            assert_eq!(strat, Strategy::Standard);
            assert!(!sched.is_empty());
            for grp in &sched.groups {
                for sub in &grp.subs {
                    assert_eq!(sub.src / 8, i, "src {} off-server", sub.src);
                    assert_eq!(sub.dst / 8, i, "dst {} off-server", sub.dst);
                }
            }
        }
    }

    #[test]
    fn pp_pair_sendrecv_pairs_stage_ranks() {
        let w = world();
        let layout = ParallelLayout::new(8, 1, 2);
        let pairs = w.pp_pairs(&layout);
        assert_eq!(pairs.len(), 1);
        let (sched, _) = pairs[0].compile(CollKind::SendRecv, 1 << 20, 0, StrategyChoice::Auto);
        // Exactly the bidirectional t ↔ t+8 boundary exchange.
        for grp in &sched.groups {
            for sub in &grp.subs {
                assert_eq!(sub.src % 8, sub.dst % 8, "{}->{}", sub.src, sub.dst);
                assert_ne!(sub.src / 8, sub.dst / 8, "boundary transfer must cross servers");
            }
        }
    }

    #[test]
    fn failure_outside_group_leaves_strategy_standard() {
        let mut wd = world();
        // Failures land on server-0 NICs only; server 1 untouched.
        wd.note_failure(0, FaultAction::FailNic);
        wd.note_failure(3, FaultAction::Degrade(0.5));
        let server1 = wd.group(&(8..16).collect::<Vec<_>>());
        let (_, strat) = server1.compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Standard, "server-1 group must not see server-0 faults");
        assert_eq!(server1.worst_server(), (1, 0.0));
        assert_eq!(server1.plan_input().degraded_servers(), 0);
        // The world group does see them.
        let (_, wstrat) =
            wd.world_group().compile(CollKind::AllGather, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(wstrat, Strategy::Balance);
    }

    #[test]
    fn group_allreduce_dataplane_exact() {
        // A cross-server DP group of 4 ranks computes exactly its own sum.
        let w = world();
        let ranks = vec![1, 5, 9, 13];
        let g = w.group(&ranks);
        let elems = 8 * 4 * 8; // divisible by channels(8) × n(4)
        let mut plane = RealPlane::new(16, elems);
        plane.fill_pattern();
        let expected = plane.expected_allreduce_over(&ranks);
        let untouched = plane.ranks[0].clone();
        let rep = g.run(
            CollKind::AllReduce,
            (elems * 4) as u64,
            StrategyChoice::Auto,
            vec![],
            &mut plane,
            elems,
        );
        assert!(!rep.crashed);
        plane.assert_ranks_equal(&ranks, &expected);
        assert_eq!(plane.ranks[0], untouched);
    }

    #[test]
    fn group_collectives_complete_under_group_failure() {
        let mut wd = world();
        wd.note_failure(0, FaultAction::FailNic);
        let layout = ParallelLayout::new(8, 1, 2);
        let boundary = wd.pp_pairs(&layout).remove(0);
        for kind in [
            CollKind::AllReduce,
            CollKind::ReduceScatter,
            CollKind::AllGather,
            CollKind::Broadcast,
            CollKind::Reduce,
            CollKind::SendRecv,
            CollKind::AllToAll,
        ] {
            let t = boundary.time_collective(kind, 1 << 20, StrategyChoice::Auto);
            assert!(t.is_some(), "{kind:?} failed under group failure");
        }
        // Forced decomposition strategies also compile for subset groups.
        let sub = wd.group(&[0, 1, 8, 9]);
        for choice in [
            StrategyChoice::Force(Strategy::R2AllReduce),
            StrategyChoice::Force(Strategy::Recursive),
        ] {
            let (sched, _) = sub.compile(CollKind::AllReduce, 1 << 20, 0, choice);
            sched.validate().unwrap();
        }
    }

    #[test]
    fn singleton_group_is_trivially_complete() {
        let w = world();
        let solo = w.group(&[5]);
        let (sched, strat) = solo.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(sched.is_empty());
        assert_eq!(strat, Strategy::Standard);
        let t = solo.time_collective(CollKind::AllReduce, 1 << 20, StrategyChoice::Auto);
        assert_eq!(t, Some(0.0));
    }

    #[test]
    fn leaf_down_world_replans_and_completes() {
        use crate::fabric::{FabricConfig, LeafSpineCfg, SwitchAction, SwitchTarget};
        let preset = Preset::simai(8);
        let fabric = FabricConfig::leaf_spine_with(LeafSpineCfg {
            pod_size: 4,
            spines: 2,
            ..LeafSpineCfg::default()
        });
        let mut w = CommWorld::new_with_fabric(&preset, 4, &fabric);
        let healthy = w
            .world_group()
            .time_collective(CollKind::AllReduce, 1 << 22, StrategyChoice::Auto)
            .expect("healthy leaf-spine allreduce");
        let leaf = w.topo().fabric().leaf_id(0, 0);
        w.note_switch_failure(SwitchTarget::Leaf(leaf), SwitchAction::Down);
        // The planner sees the reduced fabric capacity: pod-0 servers lost
        // a rail, so the strategy leaves Standard.
        let (_, strat) =
            w.world_group().compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
        assert_ne!(strat, Strategy::Standard, "leaf loss must reach strategy choice");
        assert!(w.worst_server().1 > 0.0);
        // And the collective still completes — slower — routed around the
        // dead leaf.
        let t = w
            .world_group()
            .time_collective(CollKind::AllReduce, 1 << 22, StrategyChoice::Auto)
            .expect("allreduce must survive a leaf outage");
        assert!(t > healthy, "degraded {t} vs healthy {healthy}");
        // Recovery restores the healthy plan.
        w.note_switch_failure(SwitchTarget::Leaf(leaf), SwitchAction::Up);
        let (_, strat) =
            w.world_group().compile(CollKind::AllReduce, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(strat, Strategy::Standard);
        assert!(w.known_switch_failures().is_empty());
    }

    #[test]
    fn uplink_degrade_slows_cross_pod_collectives() {
        use crate::fabric::{FabricConfig, LeafSpineCfg, SwitchAction, SwitchTarget};
        let preset = Preset::simai(8);
        let fabric = FabricConfig::leaf_spine_with(LeafSpineCfg {
            pod_size: 4,
            spines: 2,
            ..LeafSpineCfg::default()
        });
        let mut w = CommWorld::new_with_fabric(&preset, 4, &fabric);
        let healthy = w
            .world_group()
            .time_collective(CollKind::AllGather, 1 << 22, StrategyChoice::Auto)
            .unwrap();
        // Collapse every pod-0 uplink on spine 0 to 10%: cross-pod flows
        // ECMP-pinned to spine 0 crawl, so completion time grows.
        for rail in 0..8 {
            let leaf = w.topo().fabric().leaf_id(0, rail);
            w.note_switch_failure(SwitchTarget::Uplink(leaf, 0), SwitchAction::Degrade(0.1));
        }
        let t = w
            .world_group()
            .time_collective(CollKind::AllGather, 1 << 22, StrategyChoice::Auto)
            .expect("degraded uplinks must not crash");
        assert!(t > healthy, "degraded {t} vs healthy {healthy}");
    }

    #[test]
    fn epoch_mutations_via_world_are_seen_by_live_groups() {
        let mut wd = world();
        let g = wd.group(&(0..16).collect::<Vec<_>>());
        let (_, s0) = g.compile(CollKind::AllGather, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(s0, Strategy::Standard);
        wd.note_failure(0, FaultAction::FailNic);
        // The *existing* handle sees the new epoch.
        let (_, s1) = g.compile(CollKind::AllGather, 1 << 22, 0, StrategyChoice::Auto);
        assert_eq!(s1, Strategy::Balance);
    }

    #[test]
    fn shrink_bumps_epoch_exactly_once_and_reranks_survivors() {
        let mut w = CommWorld::new(&Preset::simai(4), 8);
        assert_eq!(w.active_servers(), vec![0, 1, 2, 3]);
        let e0 = w.epoch();
        let tr = w.shrink(&[1]).unwrap();
        assert_eq!(tr.kind, ElasticKind::Shrink);
        assert_eq!(tr.servers, vec![1]);
        assert_eq!(tr.active_after, 3);
        assert_eq!(w.epoch(), e0 + 1, "one membership change = one epoch bump");
        assert_eq!(tr.epoch, w.epoch());
        // Surviving GPUs re-rank contiguously around the hole.
        let act = w.active_ranks();
        assert_eq!(act.len(), 24);
        assert_eq!(act[7], 7);
        assert_eq!(act[8], 16, "rank 8 re-maps to server 2's first GPU");
        // Multi-server shrink is still a single transition / single bump.
        let e1 = w.epoch();
        let tr2 = w.shrink(&[3, 0]).unwrap();
        assert_eq!(tr2.servers, vec![0, 3], "recorded sorted");
        assert_eq!(w.epoch(), e1 + 1);
        assert_eq!(w.active_servers(), vec![2]);
        // Shrinking the last server is rejected.
        assert!(w.shrink(&[2]).is_err());
        // As is re-shrinking a dead one.
        assert!(w.shrink(&[1]).is_err());
    }

    #[test]
    fn elastic_dp_groups_shrink_around_the_dead_server() {
        let mut w = CommWorld::new(&Preset::simai(4), 8);
        let full = ParallelLayout::new(8, 4, 1);
        let dp_full = w.dp_groups_elastic(&full);
        assert_eq!(dp_full.len(), 8);
        assert_eq!(dp_full[0].ranks(), &[0, 8, 16, 24]);
        w.shrink(&[2]).unwrap();
        let shrunk = ParallelLayout::new(8, 3, 1);
        let dp = w.dp_groups_elastic(&shrunk);
        assert_eq!(dp.len(), 8);
        // Replica groups skip server 2's ranks: one rank per surviving server.
        assert_eq!(dp[0].ranks(), &[0, 8, 24]);
        assert_eq!(dp[7].ranks(), &[7, 15, 31]);
        let tp = w.tp_groups_elastic(&shrunk);
        assert_eq!(tp.len(), 3);
        assert_eq!(tp[2].ranks(), (24..32).collect::<Vec<_>>().as_slice());
        // A collective over the shrunken DP group completes even though
        // every NIC of the dead server is down.
        for nic in w.topo().nics_of_server(2) {
            w.note_failure(nic, FaultAction::FailNic);
        }
        let t = dp[0].time_collective(CollKind::AllReduce, 1 << 20, StrategyChoice::Auto);
        assert!(t.is_some(), "shrunken DP allreduce must not touch the dead server");
    }

    #[test]
    fn expand_back_restores_identity_and_plans_match_fresh_world() {
        let mut w = CommWorld::new(&Preset::simai(4), 8);
        let layout = ParallelLayout::new(8, 4, 1);
        let before: Vec<_> = w
            .dp_groups_elastic(&layout)
            .iter()
            .map(|g| g.compile_uncached(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto))
            .collect();
        w.shrink(&[1, 3]).unwrap();
        let tr = w.expand(&[1, 3]).unwrap();
        assert_eq!(tr.kind, ElasticKind::Expand);
        assert_eq!(w.active_servers(), vec![0, 1, 2, 3]);
        assert_eq!(w.epoch(), 2, "shrink + expand = two epochs");
        assert_eq!(
            w.active_ranks(),
            (0..32).collect::<Vec<_>>(),
            "full membership re-rank is the identity"
        );
        let after: Vec<_> = w
            .dp_groups_elastic(&layout)
            .iter()
            .map(|g| g.compile_uncached(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto))
            .collect();
        for ((s0, st0), (s1, st1)) in before.iter().zip(&after) {
            assert_eq!(st0, st1);
            assert_eq!(s0, s1, "round-trip membership must restore bit-identical plans");
        }
        // Double-expand is rejected.
        assert!(w.expand(&[1]).is_err());
    }

    #[test]
    fn spare_promotion_swaps_membership_in_one_bump() {
        let mut w = CommWorld::new(&Preset::simai(4), 8);
        w.set_spares(&[3]);
        assert_eq!(w.active_servers(), vec![0, 1, 2]);
        assert_eq!(w.spare_servers(), vec![3]);
        let e = w.epoch();
        let tr = w.promote_spare(1).unwrap();
        assert_eq!(tr.kind, ElasticKind::Promote);
        assert_eq!(tr.servers, vec![1, 3]);
        assert_eq!(tr.active_after, 3);
        assert_eq!(w.epoch(), e + 1, "promotion is one transition, one bump");
        assert_eq!(w.active_servers(), vec![0, 2, 3]);
        assert!(w.spare_servers().is_empty());
        assert!(w.promote_spare(0).is_err(), "no spare left");
        assert_eq!(w.elastic_log().len(), 1, "set_spares is setup, not a transition");
    }

    #[test]
    fn plan_cache_invalidates_exactly_once_per_membership_change() {
        let mut w = CommWorld::new(&Preset::simai(4), 8);
        let layout = ParallelLayout::new(8, 4, 1);
        let g = w.dp_groups_elastic(&layout).remove(0);
        let (s0, _) = g.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        let (s0b, _) = g.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(Arc::ptr_eq(&s0, &s0b));
        w.shrink(&[3]).unwrap();
        // Old-epoch entry no longer hits; recompiling under the new epoch
        // is a single fresh miss, then hits again.
        let (s1, _) = g.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(!Arc::ptr_eq(&s0, &s1));
        let (s1b, _) = g.compile(CollKind::AllReduce, 1 << 20, 0, StrategyChoice::Auto);
        assert!(Arc::ptr_eq(&s1, &s1b));
        assert_eq!(w.plan_cache_stats(), (2, 2));
    }
}
