//! Epoch-keyed memoization of compiled collective schedules.
//!
//! Compiling a schedule is pure in `(collective parameters, failure
//! epoch)`: the builders are deterministic and every health-dependent input
//! is captured by the epoch (the communicator bumps it on every
//! `note_failure` / `clear_failures`). Training and serving simulations
//! issue the *same* collective every iteration, so the per-iteration hot
//! path collapses to one hash lookup plus an `Arc` clone; a failure or
//! repair naturally invalidates every cached plan because the epoch in the
//! key changes.

use std::collections::HashMap;
use std::sync::Arc;

use crate::collectives::{CollKind, Schedule};
use crate::schedule::Strategy;

use super::StrategyChoice;

/// Cache key: everything `CommGroup::compile` depends on besides the
/// topology and channel routing, which are immutable per world
/// (`channels` is included anyway so the key stays self-describing).
/// `group` is the world-interned id of the group's rank set, so every
/// process group caches its plans independently while sharing one table —
/// two groups over the same rank set share entries by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub group: u64,
    pub kind: CollKind,
    pub bytes_per_rank: u64,
    pub elems: usize,
    pub choice: StrategyChoice,
    pub epoch: u64,
    pub channels: usize,
}

/// The memo table, with hit/miss counters for the perf benches.
#[derive(Debug)]
pub struct PlanCache {
    map: HashMap<PlanKey, (Arc<Schedule>, Strategy)>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

/// Default number of cached plans per communicator. Schedules are the
/// dominant memory cost (a 16-rank 8-channel AllReduce is ~4k groups), so
/// the cap is deliberately modest; real workloads cycle over a handful of
/// collective shapes per epoch.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 128;

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity > 0, "plan cache capacity must be positive");
        PlanCache { map: HashMap::new(), capacity, hits: 0, misses: 0 }
    }

    /// Look up a compiled plan, counting the outcome.
    pub fn get(&mut self, key: &PlanKey) -> Option<(Arc<Schedule>, Strategy)> {
        match self.map.get(key) {
            Some((sched, strategy)) => {
                self.hits += 1;
                Some((Arc::clone(sched), *strategy))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly compiled plan. At capacity, stale-epoch entries are
    /// dropped first — the epoch is monotonic, so they can never hit again;
    /// if the current epoch alone fills the cache, single entries are
    /// evicted (arbitrary order) so a working set larger than the capacity
    /// degrades gracefully instead of flushing the whole epoch.
    pub fn insert(&mut self, key: PlanKey, sched: Arc<Schedule>, strategy: Strategy) {
        if self.map.len() >= self.capacity {
            let epoch = key.epoch;
            self.map.retain(|k, _| k.epoch == epoch);
            while self.map.len() >= self.capacity {
                let Some(k) = self.map.keys().next().copied() else { break };
                self.map.remove(&k);
            }
        }
        self.map.insert(key, (sched, strategy));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Drop every entry (counters survive).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(epoch: u64, bytes: u64) -> PlanKey {
        PlanKey {
            group: 0,
            kind: CollKind::AllReduce,
            bytes_per_rank: bytes,
            elems: 0,
            choice: StrategyChoice::Auto,
            epoch,
            channels: 8,
        }
    }

    fn plan() -> Arc<Schedule> {
        Arc::new(Schedule::new("test"))
    }

    #[test]
    fn hit_and_miss_counting() {
        let mut c = PlanCache::new(4);
        let k = key(0, 1024);
        assert!(c.get(&k).is_none());
        c.insert(k, plan(), Strategy::Standard);
        let (s, strat) = c.get(&k).unwrap();
        assert_eq!(strat, Strategy::Standard);
        assert_eq!(s.label, "test");
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn epoch_is_part_of_the_key() {
        let mut c = PlanCache::new(4);
        c.insert(key(0, 1024), plan(), Strategy::Standard);
        assert!(c.get(&key(1, 1024)).is_none());
        assert!(c.get(&key(0, 1024)).is_some());
    }

    #[test]
    fn group_is_part_of_the_key() {
        let mut c = PlanCache::new(4);
        c.insert(key(0, 1024), plan(), Strategy::Standard);
        let other_group = PlanKey { group: 7, ..key(0, 1024) };
        assert!(c.get(&other_group).is_none());
        c.insert(other_group, plan(), Strategy::Balance);
        assert_eq!(c.get(&key(0, 1024)).unwrap().1, Strategy::Standard);
        assert_eq!(c.get(&other_group).unwrap().1, Strategy::Balance);
    }

    #[test]
    fn eviction_prefers_stale_epochs() {
        let mut c = PlanCache::new(2);
        c.insert(key(0, 1), plan(), Strategy::Standard);
        c.insert(key(0, 2), plan(), Strategy::Standard);
        // At capacity: inserting an epoch-1 plan drops both epoch-0 entries.
        c.insert(key(1, 3), plan(), Strategy::Balance);
        assert_eq!(c.len(), 1);
        assert!(c.get(&key(1, 3)).is_some());
    }

    #[test]
    fn eviction_keeps_cache_at_capacity_within_one_epoch() {
        let mut c = PlanCache::new(2);
        c.insert(key(5, 1), plan(), Strategy::Standard);
        c.insert(key(5, 2), plan(), Strategy::Standard);
        c.insert(key(5, 3), plan(), Strategy::Standard);
        assert_eq!(c.len(), 2, "one eviction, not a flush");
        assert!(c.get(&key(5, 3)).is_some(), "newest entry must survive");
        // Exactly one of the two older entries was evicted.
        let older = [key(5, 1), key(5, 2)];
        let surviving = older.iter().filter(|k| c.map.contains_key(k)).count();
        assert_eq!(surviving, 1);
    }
}
