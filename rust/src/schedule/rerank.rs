//! Topology-aware logical re-ranking (§6, Appendix D Algorithm 1).
//!
//! In rail-optimised fabrics, adjacent ring nodes communicate over the
//! rails they *share*. Disjoint failures on neighbours (u loses rail r, v
//! loses rail r') collapse the edge's bandwidth to |S_u ∩ S_v| rails. The
//! repair relocates "bridge" nodes with broad rail connectivity between
//! incompatible neighbours, touching only the problematic edges so most
//! RDMA connections survive.

use crate::netsim::FaultPlane;
use crate::topology::{RailId, ServerId, Topology};

/// Surviving rail sets per server.
pub fn rail_sets(topo: &Topology, faults: &FaultPlane) -> Vec<Vec<RailId>> {
    (0..topo.n_servers()).map(|s| faults.rail_set(topo, s)).collect()
}

fn intersection_size(a: &[RailId], b: &[RailId]) -> usize {
    a.iter().filter(|r| b.contains(r)).count()
}

/// Bandwidth of the weakest edge of a ring (in surviving shared rails).
pub fn min_edge_capacity(ring: &[ServerId], sets: &[Vec<RailId>]) -> usize {
    let n = ring.len();
    (0..n)
        .map(|i| intersection_size(&sets[ring[i]], &sets[ring[(i + 1) % n]]))
        .min()
        .unwrap_or(0)
}

/// Algorithm 1: bridge-based re-ranking. Takes the logical server ring and
/// the per-server surviving rail sets; returns the optimised ring.
pub fn rerank(ring_in: &[ServerId], sets: &[Vec<RailId>]) -> Vec<ServerId> {
    let mut ring: Vec<ServerId> = ring_in.to_vec();
    let n = ring.len();
    if n < 3 {
        return ring;
    }
    // B_global ← min_n |S_n|
    let b_global = ring.iter().map(|&s| sets[s].len()).min().unwrap_or(0);
    // Candidates: adjacent pairs with |S_u ∩ S_v| < B_global.
    let mut candidates: Vec<(ServerId, ServerId, usize)> = Vec::new();
    for i in 0..n {
        let u = ring[i];
        let v = ring[(i + 1) % n];
        let cap = intersection_size(&sets[u], &sets[v]);
        if cap < b_global {
            candidates.push((u, v, b_global - cap));
        }
    }
    // Sort by severity (gap size) descending.
    candidates.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)));

    for (u, v, _gap) in candidates {
        // The pair may have been separated by an earlier relocation.
        let Some(iu) = ring.iter().position(|&s| s == u) else { continue };
        if ring[(iu + 1) % ring.len()] != v {
            continue;
        }
        // Find the best bridge w.
        let mut best: Option<ServerId> = None;
        for &w in ring.iter() {
            if w == u || w == v {
                continue;
            }
            let iw = ring.iter().position(|&s| s == w).unwrap();
            let x = ring[(iw + ring.len() - 1) % ring.len()];
            let y = ring[(iw + 1) % ring.len()];
            if x == u || y == v {
                continue; // relocation would be a no-op / degenerate
            }
            let new_cap = intersection_size(&sets[u], &sets[w])
                .min(intersection_size(&sets[w], &sets[v]));
            let removal_cap = intersection_size(&sets[x], &sets[y]);
            if new_cap >= b_global && removal_cap >= b_global {
                best = Some(w);
                break;
            }
        }
        if let Some(w) = best {
            // Relocate w between u and v.
            let iw = ring.iter().position(|&s| s == w).unwrap();
            ring.remove(iw);
            let iu = ring.iter().position(|&s| s == u).unwrap();
            ring.insert(iu + 1, w);
        }
    }
    ring
}

/// Convenience: the default server ring [0, 1, …, n−1] re-ranked for the
/// current failure state.
pub fn reranked_server_order(topo: &Topology, faults: &FaultPlane) -> Vec<ServerId> {
    let ring: Vec<ServerId> = (0..topo.n_servers()).collect();
    let sets = rail_sets(topo, faults);
    rerank(&ring, &sets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    /// The §6 scenario: 4 servers, 2 rails each for clarity.
    fn sets_with(pairs: &[&[RailId]]) -> Vec<Vec<RailId>> {
        pairs.iter().map(|p| p.to_vec()).collect()
    }

    #[test]
    fn disjoint_failures_are_separated_by_bridge() {
        // u=0 lost rail 1 (keeps {0}), v=1 lost rail 0 (keeps {1}):
        // edge 0–1 has 0 shared rails. Servers 2,3 keep both rails.
        let sets = sets_with(&[&[0], &[1], &[0, 1], &[0, 1]]);
        let ring = vec![0, 1, 2, 3];
        assert_eq!(min_edge_capacity(&ring, &sets), 0);
        let out = rerank(&ring, &sets);
        // B_global = 1; every edge must now share ≥1 rail.
        assert!(min_edge_capacity(&out, &sets) >= 1, "ring {out:?}");
        // Same node set.
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn healthy_ring_is_untouched() {
        let sets = sets_with(&[&[0, 1], &[0, 1], &[0, 1], &[0, 1]]);
        let ring = vec![0, 1, 2, 3];
        assert_eq!(rerank(&ring, &sets), ring);
    }

    #[test]
    fn two_node_ring_cannot_rerank() {
        let sets = sets_with(&[&[0], &[1]]);
        assert_eq!(rerank(&[0, 1], &sets), vec![0, 1]);
    }

    #[test]
    fn rerank_preserves_membership_always() {
        // Larger randomized-ish case: 8 servers, varied sets.
        let sets = sets_with(&[
            &[0, 1, 2, 3],
            &[4, 5, 6, 7],
            &[0, 1, 2, 3, 4, 5, 6, 7],
            &[0, 2, 4, 6],
            &[1, 3, 5, 7],
            &[0, 1, 2, 3, 4, 5, 6, 7],
            &[0, 1, 4, 5],
            &[2, 3, 6, 7],
        ]);
        let ring: Vec<usize> = (0..8).collect();
        let out = rerank(&ring, &sets);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
        // Must not be worse than the input.
        assert!(min_edge_capacity(&out, &sets) >= min_edge_capacity(&ring, &sets));
    }

    #[test]
    fn integrates_with_fault_plane() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let mut e = netsim::engine_for(&t);
        let mut f = FaultPlane::new(&t);
        // Server 0 loses rails 0..6 (keeps 6,7); server 1 loses rails 2..8
        // (keeps 0,1): adjacent with empty intersection.
        for r in 0..6 {
            f.fail_nic(&t, &mut e, r);
        }
        for r in 2..8 {
            f.fail_nic(&t, &mut e, 8 + r);
        }
        let before: Vec<usize> = (0..4).collect();
        let sets = rail_sets(&t, &f);
        assert_eq!(intersection_size(&sets[0], &sets[1]), 0);
        let after = reranked_server_order(&t, &f);
        assert!(min_edge_capacity(&after, &sets) > min_edge_capacity(&before, &sets));
    }
}
