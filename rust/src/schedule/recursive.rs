//! Recursive R²CCL-AllReduce for multi-failure bandwidth spectra (§6).
//!
//! Under concurrent failures the cluster is not "one degraded server +
//! homogeneous rest": it is a spectrum of capacities. The recursive
//! scheduler forms a global ring at the slowest node's rate, peels the
//! slowest node off, builds a faster sub-ring, and repeats while bandwidth
//! variance persists; each level's data share is proportional to the
//! incremental bandwidth its members gain by excluding the slower ones.
//! Logical re-ranking (Algorithm 1) runs at every level to avoid rail
//! mismatches introduced by skipping slower nodes.

use crate::collectives::exec::ChannelRouting;
use crate::collectives::schedule::Schedule;
use crate::netsim::FaultPlane;
use crate::topology::{RankSet, ServerId, Topology};

use super::r2_allreduce::{r2_multi_allreduce_for, LevelSpec};
use super::rerank::{rail_sets, rerank};

/// Maximum recursion depth (levels beyond this gain <α each in practice).
pub const MAX_LEVELS: usize = 4;

/// Derive the level structure (server sets + data fractions) from the
/// remaining-bandwidth spectrum. `rem[s]` ∈ (0, 1] is server s's remaining
/// bandwidth fraction.
pub fn plan_levels(rem: &[f64]) -> Vec<LevelSpec> {
    let n = rem.len();
    // Sort servers slowest-first.
    let mut order: Vec<ServerId> = (0..n).collect();
    order.sort_by(|&a, &b| rem[a].partial_cmp(&rem[b]).unwrap().then(a.cmp(&b)));

    // Distinct capacity tiers, slowest first.
    let mut tiers: Vec<f64> = Vec::new();
    for &s in &order {
        if tiers.last().map(|&t| (rem[s] - t).abs() > 1e-9).unwrap_or(true) {
            tiers.push(rem[s]);
        }
    }
    // Level k includes servers with rem > tier_k's value (level 0: all).
    // Data share of level k ∝ incremental bandwidth tier_{k} − tier_{k−1}
    // (level 0 gets the base tier_0).
    let mut levels: Vec<(Vec<ServerId>, f64)> = Vec::new();
    let mut prev_tier = 0.0;
    for (k, &tier) in tiers.iter().enumerate() {
        if k >= MAX_LEVELS {
            break;
        }
        let members: Vec<ServerId> = if k == 0 {
            (0..n).collect()
        } else {
            let mut m: Vec<ServerId> = (0..n).filter(|&s| rem[s] >= tier - 1e-9).collect();
            m.sort_unstable();
            if m.len() < 2 {
                break; // a ring needs ≥2 servers (or 1 server ≥2 GPUs: allow 1)
            }
            m
        };
        levels.push((members, (tier - prev_tier).max(0.0)));
        prev_tier = tier;
    }
    // Normalise fractions.
    let total: f64 = levels.iter().map(|(_, f)| f).sum();
    let k = levels.len();
    levels
        .into_iter()
        .map(|(servers, f)| LevelSpec {
            servers,
            fraction: if total > 0.0 { f / total } else { 1.0 / k as f64 },
        })
        .collect()
}

/// Build the recursive schedule for the current failure state, applying
/// per-level logical re-ranking. World-scope convenience over
/// [`recursive_allreduce_for`].
pub fn recursive_allreduce(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    channels: usize,
) -> Schedule {
    recursive_allreduce_for(
        topo,
        faults,
        routing,
        bytes_per_rank,
        elems,
        channels,
        &RankSet::world(topo),
    )
}

/// Group-scoped recursive decomposition: the capacity spectrum, the level
/// structure and the re-ranked rings are all computed over the *group's*
/// servers only — a failure outside the group never peels a level.
pub fn recursive_allreduce_for(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    channels: usize,
    set: &RankSet,
) -> Schedule {
    let group_servers = set.servers();
    let rem: Vec<f64> = group_servers
        .iter()
        .map(|&s| 1.0 - faults.lost_bandwidth_fraction(topo, s))
        .collect();
    // plan_levels speaks indices into `rem`; map back to global server ids.
    let mut levels = plan_levels(&rem);
    for lv in &mut levels {
        lv.servers = lv.servers.iter().map(|&i| group_servers[i]).collect();
    }
    // Per-level re-ranking: order each level's servers to avoid rail
    // mismatches (Algorithm 1 over the level's sub-ring). `rail_sets` is
    // indexed by global server id, so reranking group subsets is sound.
    let sets = rail_sets(topo, faults);
    for lv in &mut levels {
        lv.servers = rerank(&lv.servers, &sets);
    }
    // Level 0 ordering must still contain every group server;
    // r2_multi_allreduce_for asserts that.
    let pipeline = set.max_ranks_per_server().max(1);
    r2_multi_allreduce_for(
        topo,
        faults,
        routing,
        bytes_per_rank,
        elems,
        &levels,
        channels,
        pipeline,
        set,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::{ChannelRouting, ExecOptions, Executor, FaultAction};
    use crate::collectives::RealPlane;
    use crate::config::TimingConfig;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    #[test]
    fn uniform_health_is_single_level() {
        let levels = plan_levels(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(levels.len(), 1);
        assert_eq!(levels[0].servers.len(), 4);
        assert!((levels[0].fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_degraded_server_gives_two_levels() {
        let levels = plan_levels(&[0.875, 1.0, 1.0, 1.0]);
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0].servers.len(), 4);
        assert_eq!(levels[1].servers, vec![1, 2, 3]);
        // Fractions: base 0.875 global, incremental 0.125 partial.
        assert!((levels[0].fraction - 0.875).abs() < 1e-9);
        assert!((levels[1].fraction - 0.125).abs() < 1e-9);
    }

    #[test]
    fn spectrum_gives_stacked_levels() {
        let levels = plan_levels(&[0.5, 0.75, 1.0, 1.0]);
        assert_eq!(levels.len(), 3);
        assert_eq!(levels[1].servers, vec![1, 2, 3]);
        assert_eq!(levels[2].servers, vec![2, 3]);
        let fsum: f64 = levels.iter().map(|l| l.fraction).sum();
        assert!((fsum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn depth_is_bounded() {
        let rem: Vec<f64> = (0..12).map(|i| 0.3 + 0.05 * i as f64).collect();
        assert!(plan_levels(&rem).len() <= MAX_LEVELS);
    }

    #[test]
    fn recursive_dataplane_is_exact() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let mut e = netsim::engine_for(&t);
        let mut f = FaultPlane::new(&t);
        // Spectrum: server 0 loses 2 NICs, server 1 loses 1.
        let script = [(0, FaultAction::FailNic), (1, FaultAction::FailNic), (8, FaultAction::FailNic)];
        f.fail_nic(&t, &mut e, 0);
        f.fail_nic(&t, &mut e, 1);
        f.fail_nic(&t, &mut e, 8);
        let channels = 2;
        let elems = 192 * 64; // lcm of level units & chunking = 192
        let bytes = (elems * 4) as u64;
        let routing = ChannelRouting::default_rails(&t, channels);
        let s = recursive_allreduce(&t, &f, &routing, bytes, elems, channels);
        s.validate().unwrap();
        let mut plane = RealPlane::new(32, elems);
        plane.fill_pattern();
        let expected = plane.expected_allreduce();
        let timing = TimingConfig::default();
        let rep = Executor::new(&t, &timing, routing, ExecOptions::default(), vec![])
            .with_initial_faults(&script)
            .run(&s, &mut plane);
        assert!(!rep.crashed);
        plane.assert_all_equal(&expected);
    }
}
