//! The α-β planner (§5.2 Data Partition Analysis, Appendix A, §8.4).
//!
//! R²CCL extends NCCL's α-β performance model with per-node bandwidth to
//! pick, per collective invocation, among: standard Ring/Tree,
//! R²CCL-Balance, single-bottleneck R²CCL-AllReduce, and recursive
//! decomposition. The Y* optimum and the X threshold below are proved in
//! Appendix A and re-verified numerically in `benches/ablations.rs`.

use crate::collectives::CollKind;

/// Strategy selected for one collective invocation (Table 1). `Hash`
/// because a forced strategy is part of the communicator's plan-cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Healthy network: NCCL's own schedule.
    Standard,
    /// NIC-level redistribution, algorithm unchanged.
    Balance,
    /// Global+partial decomposition with tailored broadcast.
    R2AllReduce,
    /// Multi-bottleneck recursive decomposition.
    Recursive,
}

/// Appendix A closed forms -------------------------------------------------

/// The a coefficient: 2(ng−1)/(ng).
pub fn coef_global(n: usize, g: usize) -> f64 {
    let ng = (n * g) as f64;
    2.0 * (ng - 1.0) / ng
}

/// The b coefficient: 2((n−1)g−1)/((n−1)g).
pub fn coef_partial(n: usize, g: usize) -> f64 {
    let m = ((n - 1) * g) as f64;
    2.0 * (m - 1.0) / m
}

/// The X threshold ng/(3ng−2): below it plain ring (Y=0) wins.
pub fn x_threshold(n: usize, g: usize) -> f64 {
    let ng = (n * g) as f64;
    ng / (3.0 * ng - 2.0)
}

/// Optimal partial-AllReduce fraction Y* for lost-bandwidth fraction `x`
/// (Appendix A): 0 below the threshold, else
/// Y* = X + X(1−X)/(X + (g(n−1)−1)·n).
pub fn optimal_y(n: usize, g: usize, x: f64) -> f64 {
    assert!((0.0..1.0).contains(&x));
    if n < 2 || x <= x_threshold(n, g) {
        return 0.0;
    }
    let denom = x + ((g * (n - 1) - 1) as f64) * n as f64;
    (x + x * (1.0 - x) / denom).min(1.0)
}

/// T(Y) of §5.2 (B = D = 1 scaling; multiply by D/B for real units):
/// max(T1, T2) + T3.
pub fn t_of_y(n: usize, g: usize, x: f64, y: f64) -> f64 {
    let a = coef_global(n, g);
    let b = coef_partial(n, g);
    let t1 = a * (1.0 - y) / (1.0 - x);
    let t2 = if x > 0.0 { b * y / x } else { f64::INFINITY * y };
    let t3 = if x > 0.0 { y / x } else { 0.0 };
    let t2 = if y == 0.0 { 0.0 } else { t2 };
    t1.max(t2) + t3
}

/// α-β completion-time models ----------------------------------------------

/// Model inputs for one collective on one (possibly degraded) topology.
#[derive(Debug, Clone)]
pub struct PlanInput {
    /// Number of servers.
    pub n: usize,
    /// GPUs per server.
    pub g: usize,
    /// Per-server healthy NIC bandwidth aggregate (bytes/s), full health.
    pub server_bw: f64,
    /// Remaining bandwidth fraction per server (1.0 = healthy);
    /// length n. The X of server i is 1 − rem[i].
    pub rem: Vec<f64>,
    /// Per-hop latency α.
    pub alpha: f64,
}

impl PlanInput {
    pub fn uniform(n: usize, g: usize, server_bw: f64, alpha: f64) -> Self {
        PlanInput { n, g, server_bw, rem: vec![1.0; n], alpha }
    }

    /// Lost fraction of the most degraded server.
    pub fn worst_x(&self) -> f64 {
        1.0 - self.rem.iter().cloned().fold(1.0_f64, f64::min)
    }

    pub fn n_ranks(&self) -> usize {
        self.n * self.g
    }

    pub fn degraded_servers(&self) -> usize {
        self.rem.iter().filter(|&&r| r < 1.0).count()
    }
}

/// Ring collective time with per-server bottleneck bandwidth:
/// 2(N−1)α + 2(N−1)/N · D / B_min (AllReduce), (N−1)/N variants for
/// RS/AG, D/B for broadcast-like.
pub fn ring_time(kind: CollKind, input: &PlanInput, bytes: f64, balanced: bool) -> f64 {
    let nr = input.n_ranks() as f64;
    let k = input.g as f64; // NICs per server (1:1 with GPUs in our topologies)
    let bmin = input
        .rem
        .iter()
        .map(|r| {
            let failed = (k * (1.0 - r)).round();
            if balanced || failed == 0.0 {
                // Balance: the server's traffic spreads over healthy NICs →
                // effective rate = remaining aggregate bandwidth.
                r * input.server_bw
            } else {
                // Unbalanced hot repair: the failed channels pile onto one
                // backup NIC, which then carries (1 + failed) channels; the
                // ring is throttled by its slowest channel → B / (1+f).
                input.server_bw / (1.0 + failed)
            }
        })
        .fold(f64::INFINITY, f64::min)
        .max(1.0);
    let (vol_factor, steps) = match kind {
        CollKind::AllReduce => (2.0 * (nr - 1.0) / nr, 2.0 * (nr - 1.0)),
        CollKind::ReduceScatter | CollKind::AllGather => ((nr - 1.0) / nr, nr - 1.0),
        CollKind::Broadcast | CollKind::Reduce => (1.0, nr - 1.0),
        CollKind::SendRecv => (1.0, 1.0),
        CollKind::AllToAll => ((nr - 1.0) / nr, nr - 1.0),
    };
    steps * input.alpha + vol_factor * bytes / bmin
}

/// R²CCL-AllReduce completion estimate: duplex-aware per-server volume
/// model (Fig 5 accounting — the degraded server sheds Y of the ring
/// volume and pays only Y per direction for inject‖deliver; validated
/// against the fluid simulation, see EXPERIMENTS.md §Perf Y-sweep).
/// Appendix A's serial T(Y) is kept in [`t_of_y`] for the ablation; this
/// overlapped model is what the runtime planner uses.
pub fn r2_allreduce_time(input: &PlanInput, bytes: f64) -> f64 {
    let x = input.worst_x();
    if x <= 0.0 {
        return ring_time(CollKind::AllReduce, input, bytes, true);
    }
    let n = input.n;
    let g = input.g;
    let y = if n == 2 { (2.0 * x).min(0.5) } else { optimal_y(n, g, x).max(x.min(0.5)) };
    let nr = (n * g) as f64;
    let nh = (((n - 1).max(1)) * g) as f64;
    // Per-direction volumes (×D): degraded server runs the global ring on
    // (1−Y) plus one Y-slice each way; healthy servers run both rings plus
    // the broadcast walk through their leads.
    let vol_degraded = 2.0 * (1.0 - y) * (nr - 1.0) / nr + y;
    let vol_healthy =
        2.0 * (1.0 - y) * (nr - 1.0) / nr + 2.0 * y * (nh - 1.0).max(1.0) / nh + 0.5 * y;
    let t_bytes = (vol_degraded / (1.0 - x)).max(vol_healthy) * bytes / input.server_bw;
    // α terms: ring steps + stage-2 pipeline coordination.
    let alpha = 2.0 * (nr - 1.0) * input.alpha + 16.0 * (n as f64) * input.alpha;
    alpha + t_bytes
}

/// Pick the strategy for a collective (§8.4: α-β driven, size-aware).
pub fn choose_strategy(kind: CollKind, input: &PlanInput, bytes: f64) -> Strategy {
    if input.degraded_servers() == 0 {
        return Strategy::Standard;
    }
    if kind != CollKind::AllReduce {
        // Table 1: everything except throughput-bound AllReduce uses
        // Balance (including latency-bound AllReduce below).
        return Strategy::Balance;
    }
    if input.degraded_servers() > 1 {
        return Strategy::Recursive;
    }
    // Single failure, AllReduce: compare α-β estimates.
    let t_bal = ring_time(kind, input, bytes, true);
    let t_r2 = r2_allreduce_time(input, bytes);
    if t_r2 < t_bal {
        Strategy::R2AllReduce
    } else {
        Strategy::Balance
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_matches_paper_practical_third() {
        // Paper: in practice X < 1/3 → standard ring. ng/(3ng−2) → 1/3 as
        // ng grows.
        let th = x_threshold(2, 8);
        assert!((th - 16.0 / 46.0).abs() < 1e-12);
        assert!(x_threshold(64, 8) > 0.333 && x_threshold(64, 8) < 0.3345);
    }

    #[test]
    fn y_zero_below_threshold() {
        assert_eq!(optimal_y(2, 8, 0.125), 0.0);
        assert_eq!(optimal_y(64, 8, 0.2), 0.0);
    }

    #[test]
    fn y_star_above_threshold_minimises_t() {
        let (n, g, x) = (2usize, 8usize, 0.5f64);
        let y_star = optimal_y(n, g, x);
        assert!(y_star > x && y_star < 1.0, "y*={y_star}");
        let t_star = t_of_y(n, g, x, y_star);
        // Sweep Y; nothing beats Y* (within numeric tolerance).
        for i in 0..=100 {
            let y = i as f64 / 100.0;
            assert!(
                t_of_y(n, g, x, y) >= t_star - 1e-9,
                "T({y}) = {} < T(Y*) = {t_star}",
                t_of_y(n, g, x, y)
            );
        }
    }

    #[test]
    fn t_at_y_zero_is_degraded_ring() {
        let (n, g, x) = (4usize, 8usize, 0.25f64);
        let t0 = t_of_y(n, g, x, 0.0);
        assert!((t0 - coef_global(n, g) / 0.75).abs() < 1e-12);
    }

    #[test]
    fn below_threshold_standard_ring_wins_everywhere() {
        // Appendix A step 3, branch 1: T non-decreasing on [0,1].
        let (n, g, x) = (2usize, 8usize, 0.2f64);
        assert!(x < x_threshold(n, g));
        let t0 = t_of_y(n, g, x, 0.0);
        for i in 1..=50 {
            let y = i as f64 / 50.0;
            assert!(t_of_y(n, g, x, y) >= t0 - 1e-12);
        }
    }

    #[test]
    fn strategy_table1_mapping() {
        let mut input = PlanInput::uniform(2, 8, 400e9, 5e-6);
        // Healthy → Standard.
        assert_eq!(choose_strategy(CollKind::AllReduce, &input, 1e9), Strategy::Standard);
        input.rem[0] = 0.875;
        // Non-AllReduce collectives → Balance.
        for k in [CollKind::AllGather, CollKind::ReduceScatter, CollKind::Broadcast, CollKind::SendRecv] {
            assert_eq!(choose_strategy(k, &input, 1e9), Strategy::Balance);
        }
        // Tiny AllReduce (latency-bound) → Balance.
        assert_eq!(choose_strategy(CollKind::AllReduce, &input, 8.0), Strategy::Balance);
        // Multi-failure → Recursive.
        input.rem[1] = 0.75;
        assert_eq!(choose_strategy(CollKind::AllReduce, &input, 1e9), Strategy::Recursive);
    }

    #[test]
    fn severe_single_failure_prefers_r2_allreduce() {
        let mut input = PlanInput::uniform(2, 8, 400e9, 5e-6);
        input.rem[0] = 0.5; // X = 0.5 > threshold
        let s = choose_strategy(CollKind::AllReduce, &input, 1e9);
        assert_eq!(s, Strategy::R2AllReduce);
    }

    #[test]
    fn ring_time_monotone_in_size_and_degradation() {
        let input = PlanInput::uniform(4, 8, 200e9, 5e-6);
        let t1 = ring_time(CollKind::AllReduce, &input, 1e8, true);
        let t2 = ring_time(CollKind::AllReduce, &input, 2e8, true);
        assert!(t2 > t1);
        let mut deg = input.clone();
        deg.rem[2] = 0.875;
        assert!(ring_time(CollKind::AllReduce, &deg, 1e8, true) > t1);
    }
}
