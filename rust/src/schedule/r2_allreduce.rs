//! R²CCL-AllReduce (§5.2) and its recursive generalisation (§6).
//!
//! The decomposition: split the data into slices; slice 0 runs a *global*
//! ring AllReduce over every server (throttled by the most degraded one),
//! while slice k ≥ 1 runs a *partial* AllReduce that excludes the k most
//! degraded servers and therefore runs at the healthier nodes' full speed.
//! Excluded servers still contribute: each reduces its slice intra-node
//! (NVLink), injects it into the partial ring via its lead GPU, and the
//! completed result is walked back around the healthy ring and delivered
//! to the excluded servers — the paper's "tailored broadcast" stage
//! (Figure 5). All stages are chunk-pipelined and run concurrently in the
//! fluid simulation, so duplex bandwidth and NVLink/NIC overlap are
//! exploited exactly as the implementation's channel partitioning does.

use crate::collectives::exec::ChannelRouting;
use crate::collectives::ring::{
    ring_allreduce, rings_for_ranks, rings_in_server_order, split_even, RingSpec,
};
use crate::collectives::schedule::{DataOp, Schedule, TransferGroup};
use crate::netsim::FaultPlane;
use crate::topology::{GpuId, RankSet, ServerId, Topology};

use super::balance::apply_balance;

/// One decomposition level.
#[derive(Debug, Clone)]
pub struct LevelSpec {
    /// Servers participating in this level's ring (level 0: all).
    pub servers: Vec<ServerId>,
    /// Fraction of the data handled at this level (fractions sum to 1).
    pub fraction: f64,
}

/// Ring spec over a subset of servers, all GPUs participating (channel c
/// starts each server's visit at local GPU c, as in
/// [`crate::collectives::ring::nccl_rings`]). World-scope convenience over
/// [`rings_in_server_order`].
pub fn rings_for_servers(topo: &Topology, channels: usize, servers: &[ServerId]) -> RingSpec {
    rings_in_server_order(&RankSet::world(topo), servers, channels)
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

/// Split `elems` into per-level element slices, all aligned to the lcm of
/// every level's data-plane unit (channels·ranks for the rings,
/// channels·pipeline for the broadcast chunking) so element maps stay
/// exact. When `elems` itself is not lcm-aligned the whole schedule runs
/// timing-only (all slices report length 0 → `DataOp::None`); byte volumes
/// still follow the fractions.
fn slice_elems(
    elems: usize,
    levels: &[LevelSpec],
    channels: usize,
    pipeline: usize,
    set: &RankSet,
) -> Vec<(usize, usize)> {
    let mut unit = channels * pipeline;
    for lv in levels {
        let level_ranks: usize = lv.servers.iter().map(|&s| set.ranks_on(s).len()).sum();
        unit = lcm(unit, channels * level_ranks.max(1));
    }
    if elems == 0 || elems % unit != 0 {
        return vec![(0, 0); levels.len()];
    }
    let blocks = elems / unit;
    // Allocate whole blocks per fraction (largest-remainder rounding).
    let mut alloc: Vec<usize> = levels
        .iter()
        .map(|l| (l.fraction * blocks as f64).floor() as usize)
        .collect();
    let mut rest = blocks - alloc.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..levels.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = levels[a].fraction * blocks as f64 - alloc[a] as f64;
        let rb = levels[b].fraction * blocks as f64 - alloc[b] as f64;
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });
    let mut i = 0;
    while rest > 0 {
        alloc[order[i % order.len()]] += 1;
        rest -= 1;
        i += 1;
    }
    let mut out = Vec::with_capacity(levels.len());
    let mut off = 0usize;
    for a in alloc {
        let len = a * unit;
        out.push((off, len));
        off += len;
    }
    out
}

/// Build the full multi-level schedule.
///
/// * `levels[0]` must contain every server; later levels drop the most
///   degraded ones (each level's server set must be a subset of the
///   previous).
/// * `pipeline` is the chunk pipelining depth of the broadcast walks.
///
/// World-scope convenience over [`r2_multi_allreduce_for`].
#[allow(clippy::too_many_arguments)]
pub fn r2_multi_allreduce(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    levels: &[LevelSpec],
    channels: usize,
    pipeline: usize,
) -> Schedule {
    r2_multi_allreduce_for(
        topo,
        faults,
        routing,
        bytes_per_rank,
        elems,
        levels,
        channels,
        pipeline,
        &RankSet::world(topo),
    )
}

/// Group-scoped multi-level schedule: the decomposition runs over `set`'s
/// ranks only, level server sets are (possibly re-ranked) subsets of the
/// *group's* servers, and each server's intra-node stages walk the group's
/// member GPUs with the group lead as injection point. With the world rank
/// set this is exactly the original world-scope decomposition.
#[allow(clippy::too_many_arguments)]
pub fn r2_multi_allreduce_for(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    levels: &[LevelSpec],
    channels: usize,
    pipeline: usize,
    set: &RankSet,
) -> Schedule {
    assert!(!levels.is_empty());
    {
        let mut l0 = levels[0].servers.clone();
        l0.sort_unstable();
        assert_eq!(l0, set.servers(), "level 0 must cover every group server");
    }
    let frac_sum: f64 = levels.iter().map(|l| l.fraction).sum();
    assert!((frac_sum - 1.0).abs() < 1e-9, "fractions must sum to 1, got {frac_sum}");

    let mut sched = Schedule::new("r2-allreduce");
    let slices = slice_elems(elems, levels, channels, pipeline, set);
    // Bytes per level proportional to element slices when data-plane-exact,
    // else to fractions.
    let exact = slices.iter().map(|&(_, l)| l).sum::<usize>() == elems && elems > 0;
    let level_bytes: Vec<u64> = if exact {
        slices.iter().map(|&(_, len)| (len * 4) as u64).collect()
    } else {
        let mut v: Vec<u64> = levels
            .iter()
            .map(|l| (bytes_per_rank as f64 * l.fraction).round() as u64)
            .collect();
        let diff = bytes_per_rank as i64 - v.iter().sum::<u64>() as i64;
        let last = v.len() - 1;
        v[last] = (v[last] as i64 + diff) as u64;
        v
    };

    for (k, lv) in levels.iter().enumerate() {
        let (e_off, e_len) = slices[k];
        let b = level_bytes[k];
        if b == 0 && e_len == 0 {
            continue;
        }
        let spec = rings_in_server_order(set, &lv.servers, channels);
        // The level's AllReduce over its member servers.
        let mut ar = ring_allreduce(&spec, b, e_len);
        ar.offset_elems(e_off);
        let ar_exits_local = ar.exit_groups();
        let ar_off = sched.append(ar);
        let ar_exits: Vec<usize> = ar_exits_local.iter().map(|&i| i + ar_off).collect();

        // Excluded servers (members of level 0 but not of this level)
        // contribute via the tailored broadcast stage.
        if k > 0 {
            let excluded: Vec<ServerId> = set
                .servers()
                .iter()
                .copied()
                .filter(|s| !lv.servers.contains(s))
                .collect();
            emit_tailored_broadcast(
                set,
                &mut sched,
                &lv.servers,
                &excluded,
                b,
                (e_off, e_len),
                channels,
                pipeline,
                &ar_exits,
            );
        }
    }
    // Spread any traffic bound to dead NICs across healthy ones.
    apply_balance(topo, faults, routing, &sched)
}

/// The single-failure R²CCL-AllReduce of §5.2: global (1−Y) + partial (Y)
/// excluding `degraded_server`. World-scope convenience over
/// [`r2_allreduce_schedule_for`].
#[allow(clippy::too_many_arguments)]
pub fn r2_allreduce_schedule(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    degraded_server: ServerId,
    y: f64,
    channels: usize,
) -> Schedule {
    r2_allreduce_schedule_for(
        topo,
        faults,
        routing,
        bytes_per_rank,
        elems,
        degraded_server,
        y,
        channels,
        &RankSet::world(topo),
    )
}

/// Group-scoped single-failure decomposition: the global ring runs over the
/// group's ranks, the partial ring excludes the degraded *group* server,
/// and the tailored broadcast walks the group leads. `degraded_server` must
/// host group ranks.
#[allow(clippy::too_many_arguments)]
pub fn r2_allreduce_schedule_for(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    bytes_per_rank: u64,
    elems: usize,
    degraded_server: ServerId,
    y: f64,
    channels: usize,
    set: &RankSet,
) -> Schedule {
    if y <= 0.0 || set.n_servers() < 2 {
        // Degenerates to the standard (balanced) ring over the group.
        let spec = rings_for_ranks(set, channels);
        let ar = ring_allreduce(&spec, bytes_per_rank, elems);
        return apply_balance(topo, faults, routing, &ar);
    }
    let all: Vec<ServerId> = set.servers().to_vec();
    let healthy: Vec<ServerId> = all.iter().copied().filter(|&s| s != degraded_server).collect();
    let levels = vec![
        LevelSpec { servers: all, fraction: 1.0 - y },
        LevelSpec { servers: healthy, fraction: y },
    ];
    let pipeline = set.max_ranks_per_server().max(1);
    r2_multi_allreduce_for(
        topo,
        faults,
        routing,
        bytes_per_rank,
        elems,
        &levels,
        channels,
        pipeline,
        set,
    )
}

/// Stage 2 (Figure 5): for each excluded server — intra-node reduce of the
/// group's member GPUs to the group lead, inject into the partial ring's
/// first member (reduce), walk the completed slice around the member
/// leads, deliver back to the excluded leads, and intra-node broadcast
/// everywhere. Scoped to `set`: only group ranks participate, and each
/// server's lead is the group's lowest rank on it.
#[allow(clippy::too_many_arguments)]
fn emit_tailored_broadcast(
    set: &RankSet,
    sched: &mut Schedule,
    members: &[ServerId],
    excluded: &[ServerId],
    bytes: u64,
    (e_off, e_len): (usize, usize),
    channels: usize,
    pipeline: usize,
    ar_exits: &[usize],
) {
    let lead = |s: ServerId| set.lead(s).expect("tailored-broadcast server must host group ranks");
    let chan_bytes = split_even(bytes, channels);
    // Element slices per channel (exact only when divisible).
    let chan_ranges: Option<Vec<(usize, usize)>> = if e_len > 0 && e_len % channels == 0 {
        let per = e_len / channels;
        Some((0..channels).map(|c| (e_off + c * per, per)).collect())
    } else {
        None
    };

    for c in 0..channels {
        let cb = chan_bytes[c];
        let crange = chan_ranges.as_ref().map(|r| r[c]);
        let chunk_bytes = split_even(cb, pipeline);
        let chunk_ranges: Option<Vec<(usize, usize)>> = crange.and_then(|(off, len)| {
            if len % pipeline == 0 {
                let per = len / pipeline;
                Some((0..pipeline).map(|k| (off + k * per, per)).collect())
            } else {
                None
            }
        });
        let op_of = |k: usize, reduce: bool| match &chunk_ranges {
            Some(rs) => {
                let (off, len) = rs[k];
                if reduce {
                    DataOp::Reduce { off, len }
                } else {
                    DataOp::Copy { off, len }
                }
            }
            None => DataOp::None,
        };

        // (a) Intra-node reduce at each excluded server: a pipelined NVLink
        //     *chain* g_{g−1} → … → g_1 → lead. Each hop adds the arriving
        //     accumulated slice into its own buffer and forwards — no GPU's
        //     NVLink port carries more than one slice (a star into the lead
        //     would multiply the lead's ingress by g−1).
        let mut intra_done: Vec<Vec<Vec<usize>>> = Vec::new(); // [excluded][chunk][dep]
        for &b in excluded {
            let gpus: Vec<GpuId> = set.ranks_on(b).to_vec();
            let l = lead(b);
            debug_assert_eq!(gpus[0], l);
            // Chain edges: gpus[g-1] → gpus[g-2] → … → gpus[0] (= lead).
            let mut prev_edge: Vec<Option<usize>> = vec![None; pipeline];
            let mut fifo: Vec<Option<usize>> = vec![None; gpus.len()];
            let mut last_into_lead: Vec<Vec<usize>> = vec![Vec::new(); pipeline];
            for e in (1..gpus.len()).rev() {
                let (src, dst) = (gpus[e], gpus[e - 1]);
                for k in 0..pipeline {
                    let mut deps = Vec::new();
                    if let Some(p) = prev_edge[k] {
                        deps.push(p); // accumulated slice arrived at src
                    }
                    if let Some(p) = fifo[e] {
                        deps.push(p);
                    }
                    let idx = sched.push(TransferGroup::single(
                        c,
                        src,
                        dst,
                        chunk_bytes[k],
                        deps,
                        op_of(k, true),
                    ));
                    prev_edge[k] = Some(idx);
                    fifo[e] = Some(idx);
                    if e == 1 {
                        last_into_lead[k] = vec![idx];
                    }
                }
            }
            if gpus.len() == 1 {
                // Single-GPU server: nothing to reduce.
            }
            intra_done.push(last_into_lead);
        }

        // (b) Injection: each excluded lead reduces its slice into the first
        //     member's lead. Gated on the partial ring having finished that
        //     slice (ar_exits) so the reduce lands on the completed partial
        //     result.
        let first = lead(members[0]);
        let mut inject_done: Vec<Vec<usize>> = vec![Vec::new(); pipeline];
        for (bi, &b) in excluded.iter().enumerate() {
            let l = lead(b);
            let mut fifo_prev: Option<usize> = None;
            for k in 0..pipeline {
                let mut deps: Vec<usize> = intra_done[bi][k].clone();
                deps.extend_from_slice(ar_exits);
                if let Some(p) = fifo_prev {
                    deps.push(p);
                }
                let idx = sched.push(TransferGroup::single(
                    c,
                    l,
                    first,
                    chunk_bytes[k],
                    deps,
                    op_of(k, true),
                ));
                fifo_prev = Some(idx);
                inject_done[k].push(idx);
            }
        }

        // (c) Walk the completed slice around the member leads, then out to
        //     every excluded lead (branching from the last member).
        //     Nodes: m0 → m1 → … → m_last → {x0, x1, …}
        //     arrivals[(lead, per-chunk dep lists)] feeds the intra
        //     broadcasts of stage (d).
        let last_member = lead(*members.last().unwrap());
        // (src, dst, dst_server, is_delivery)
        let mut walk: Vec<(GpuId, GpuId, ServerId, bool)> = Vec::new();
        for w in members.windows(2) {
            walk.push((lead(w[0]), lead(w[1]), w[1], false));
        }
        for &x in excluded {
            walk.push((last_member, lead(x), x, true));
        }
        // Member 0's arrival of chunk k = all injections of chunk k.
        let mut arrivals: Vec<(ServerId, Vec<Vec<usize>>)> =
            vec![(members[0], inject_done.clone())];
        // prev_arrival[k]: deps for the next member→member edge.
        let mut prev_arrival: Vec<Vec<usize>> = inject_done.clone();
        // branch_from[k]: deps for deliveries out of the last member.
        let mut branch_from: Vec<Vec<usize>> = inject_done.clone();
        let mut edge_prev: Vec<Option<usize>> = vec![None; walk.len()];
        for (ei, &(src, dst, dst_server, is_delivery)) in walk.iter().enumerate() {
            let mut per_chunk: Vec<Vec<usize>> = Vec::with_capacity(pipeline);
            for k in 0..pipeline {
                let mut deps: Vec<usize> = if is_delivery {
                    branch_from[k].clone()
                } else {
                    prev_arrival[k].clone()
                };
                if let Some(p) = edge_prev[ei] {
                    deps.push(p); // FIFO on the edge
                }
                let idx = sched.push(TransferGroup::single(
                    c,
                    src,
                    dst,
                    chunk_bytes[k],
                    deps,
                    op_of(k, false),
                ));
                edge_prev[ei] = Some(idx);
                per_chunk.push(vec![idx]);
            }
            if !is_delivery {
                prev_arrival = per_chunk.clone();
                if dst == last_member {
                    branch_from = per_chunk.clone();
                }
            }
            arrivals.push((dst_server, per_chunk));
        }

        // (d) Intra-node broadcast at every server whose lead received the
        //     completed slice: a pipelined NVLink chain over the group's
        //     member GPUs, lead → g_1 → … → g_{m−1} (a star would multiply
        //     the lead's egress by m−1).
        for (server, per_chunk) in &arrivals {
            let gpus: Vec<GpuId> = set.ranks_on(*server).to_vec();
            debug_assert_eq!(gpus[0], lead(*server));
            let mut prev_edge: Vec<Vec<usize>> = per_chunk.clone();
            for e in 1..gpus.len() {
                let (src, dst) = (gpus[e - 1], gpus[e]);
                let mut fifo: Option<usize> = None;
                let mut this_edge: Vec<Vec<usize>> = Vec::with_capacity(pipeline);
                for k in 0..pipeline {
                    let mut deps = prev_edge[k].clone();
                    if let Some(p) = fifo {
                        deps.push(p);
                    }
                    let idx = sched.push(TransferGroup::single(
                        c,
                        src,
                        dst,
                        chunk_bytes[k],
                        deps,
                        op_of(k, false),
                    ));
                    fifo = Some(idx);
                    this_edge.push(vec![idx]);
                }
                prev_edge = this_edge;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::{ChannelRouting, ExecOptions, Executor, FaultAction};
    use crate::collectives::{PhantomPlane, RealPlane};
    use crate::config::TimingConfig;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    fn setup() -> (Topology, crate::netsim::Engine, FaultPlane) {
        let t = Topology::build(&TopologyConfig::testbed_h100());
        let e = netsim::engine_for(&t);
        let f = FaultPlane::new(&t);
        (t, e, f)
    }

    #[test]
    fn subset_rings_cover_subset() {
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let spec = rings_for_servers(&t, 4, &[0, 2, 3]);
        assert_eq!(spec.n_ranks(), 24);
        for ring in &spec.rings {
            assert!(ring.iter().all(|&g| t.server_of_gpu(g) != 1));
        }
    }

    #[test]
    fn schedule_is_valid_dag() {
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let routing = ChannelRouting::default_rails(&t, 4);
        let s = r2_allreduce_schedule(&t, &f, &routing, 1 << 24, 0, 0, 0.25, 4);
        s.validate().unwrap();
    }

    #[test]
    fn y_zero_degenerates_to_balanced_ring() {
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let routing = ChannelRouting::default_rails(&t, 4);
        let s = r2_allreduce_schedule(&t, &f, &routing, 1 << 20, 0, 0, 0.0, 4);
        assert!(s.label.contains("balance"));
        // Same wire volume as a plain ring AllReduce.
        assert_eq!(s.total_bytes(), 2 * 15 * (1u64 << 20));
    }

    #[test]
    fn dataplane_correct_single_failure() {
        // The critical correctness property: the decomposed AllReduce
        // computes exactly the same result as a plain sum.
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let channels = 2;
        let pipeline = 8;
        // elems: divisible by channels·16 (global) and channels·8·pipeline.
        let elems = channels * 16 * 8 * pipeline * 2;
        let bytes = (elems * 4) as u64;
        let routing = ChannelRouting::default_rails(&t, channels);
        let s = r2_allreduce_schedule(&t, &f, &routing, bytes, elems, 0, 0.25, channels);
        s.validate().unwrap();
        let mut plane = RealPlane::new(16, elems);
        plane.fill_pattern();
        let expected = plane.expected_allreduce();
        let timing = TimingConfig::default();
        let rep = Executor::new(&t, &timing, routing, ExecOptions::default(), vec![])
            .with_initial_faults(&[(0, FaultAction::FailNic)])
            .run(&s, &mut plane);
        assert!(!rep.crashed);
        plane.assert_all_equal(&expected);
    }

    #[test]
    fn dataplane_correct_multi_level() {
        // Three levels on a 4-server cluster (recursive decomposition).
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let mut e = netsim::engine_for(&t);
        let mut f = FaultPlane::new(&t);
        f.fail_nic(&t, &mut e, 0); // server 0 degraded badly
        f.fail_nic(&t, &mut e, 1);
        f.fail_nic(&t, &mut e, 8); // server 1 degraded lightly
        let channels = 2;
        let levels = vec![
            LevelSpec { servers: vec![0, 1, 2, 3], fraction: 0.5 },
            LevelSpec { servers: vec![1, 2, 3], fraction: 0.25 },
            LevelSpec { servers: vec![2, 3], fraction: 0.25 },
        ];
        let elems = 192 * 32; // lcm(level units, channels*pipeline) = 192
        let bytes = (elems * 4) as u64;
        let routing = ChannelRouting::default_rails(&t, channels);
        let s = r2_multi_allreduce(&t, &f, &routing, bytes, elems, &levels, channels, 8);
        s.validate().unwrap();
        let mut plane = RealPlane::new(32, elems);
        plane.fill_pattern();
        let expected = plane.expected_allreduce();
        let timing = TimingConfig::default();
        let rep = Executor::new(&t, &timing, routing, ExecOptions::default(), vec![])
            .with_initial_faults(&[
                (0, FaultAction::FailNic),
                (1, FaultAction::FailNic),
                (8, FaultAction::FailNic),
            ])
            .run(&s, &mut plane);
        assert!(!rep.crashed, "timeline: {:?}", rep.timeline);
        plane.assert_all_equal(&expected);
    }

    #[test]
    fn group_scoped_decomposition_dataplane_exact() {
        // A group over servers {1, 2, 3} of a 4-server cluster (a DP
        // replica set excluding server 0 entirely) with a failure on a
        // *member* server: the decomposition must run over group ranks
        // only, inject through group leads, and still produce the exact
        // group sum — while server 0's buffers stay untouched.
        let t = Topology::build(&TopologyConfig::simai_a100(4));
        let mut e = netsim::engine_for(&t);
        let mut f = FaultPlane::new(&t);
        f.fail_nic(&t, &mut e, 8); // server 1, a group member
        let channels = 2;
        let group_ranks: Vec<usize> = (8..32).collect();
        let set = RankSet::new(&t, &group_ranks);
        // elems divisible by channels·24 (global level), channels·16
        // (partial level) and channels·pipeline(8).
        let elems = 2 * 48 * 8 * 4;
        let bytes = (elems * 4) as u64;
        let routing = ChannelRouting::default_rails(&t, channels);
        let s = r2_allreduce_schedule_for(&t, &f, &routing, bytes, elems, 1, 0.25, channels, &set);
        s.validate().unwrap();
        // Every transfer stays within the group.
        for g in &s.groups {
            for sub in &g.subs {
                assert!(set.contains(sub.src) && set.contains(sub.dst), "{}->{}", sub.src, sub.dst);
            }
        }
        let mut plane = RealPlane::new(32, elems);
        plane.fill_pattern();
        let before_outside = plane.ranks[0].clone();
        let expected = plane.expected_allreduce_over(&group_ranks);
        let timing = TimingConfig::default();
        let rep = Executor::new(&t, &timing, routing, ExecOptions::default(), vec![])
            .with_initial_faults(&[(8, FaultAction::FailNic)])
            .run(&s, &mut plane);
        assert!(!rep.crashed);
        plane.assert_ranks_equal(&group_ranks, &expected);
        assert_eq!(plane.ranks[0], before_outside, "non-member buffers must be untouched");
    }

    #[test]
    fn r2_reduces_degraded_server_io() {
        // §5.2: the decomposition cuts the degraded server's wire volume
        // from ~2D to ~2D−YD.
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let routing = ChannelRouting::default_rails(&t, 8);
        let d = 1u64 << 24;
        let y = 0.25;
        let plain = r2_allreduce_schedule(&t, &f, &routing, d, 0, 0, 0.0, 8);
        let decomp = r2_allreduce_schedule(&t, &f, &routing, d, 0, 0, y, 8);
        let io_plain = plain.server_io_bytes(|g| t.server_of_gpu(g), 2);
        let io_dec = decomp.server_io_bytes(|g| t.server_of_gpu(g), 2);
        // Degraded server 0 sends strictly less under the decomposition.
        assert!(
            (io_dec[0].0 as f64) < 0.93 * io_plain[0].0 as f64,
            "decomposed {} vs plain {}",
            io_dec[0].0,
            io_plain[0].0
        );
    }

    #[test]
    fn r2_faster_than_balance_for_large_messages() {
        // Fig 15 ordering at the top end.
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let timing = TimingConfig::default();
        let routing = ChannelRouting::default_rails(&t, 8);
        let d: u64 = 1 << 29;
        let bal = r2_allreduce_schedule(&t, &f, &routing, d, 0, 0, 0.0, 8);
        let t_bal = Executor::new(&t, &timing, routing.clone(), ExecOptions::default(), vec![])
            .with_initial_faults(&[(0, FaultAction::FailNic)])
            .run(&bal, &mut PhantomPlane)
            .completion_or_panic();
        let dec = r2_allreduce_schedule(&t, &f, &routing, d, 0, 0, 0.4, 8);
        let t_dec = Executor::new(&t, &timing, routing.clone(), ExecOptions::default(), vec![])
            .with_initial_faults(&[(0, FaultAction::FailNic)])
            .run(&dec, &mut PhantomPlane)
            .completion_or_panic();
        assert!(
            t_dec < t_bal,
            "decomposed {:.3}ms vs balance {:.3}ms",
            t_dec * 1e3,
            t_bal * 1e3
        );
    }
}
