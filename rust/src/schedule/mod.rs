//! The paper's scheduling contributions: R²CCL-Balance (§5.1),
//! R²CCL-AllReduce (§5.2), recursive multi-failure decomposition +
//! topology-aware logical re-ranking (§6), and the α-β planner that picks
//! among them per collective invocation (§8.4).

pub mod balance;
pub mod planner;
pub mod r2_allreduce;
pub mod recursive;
pub mod rerank;

pub use balance::{apply_balance, weighted_split};
pub use planner::{choose_strategy, optimal_y, ring_time, t_of_y, x_threshold, PlanInput, Strategy};
pub use r2_allreduce::{
    r2_allreduce_schedule, r2_allreduce_schedule_for, r2_multi_allreduce, r2_multi_allreduce_for,
    rings_for_servers, LevelSpec,
};
pub use recursive::{plan_levels, recursive_allreduce, recursive_allreduce_for};
pub use rerank::{min_edge_capacity, rail_sets, rerank, reranked_server_order};
