//! R²CCL-Balance (§5.1): NIC-level load balancing that leaves the
//! collective algorithm untouched.
//!
//! NCCL's schedule fixes how much inter-server data each server moves
//! (already the semantic minimum for core collectives); the only remaining
//! degree of freedom is *which NICs carry it*. When a NIC fails, Balance
//! splits every transfer that would have used it across the server's
//! remaining healthy NICs in proportion to their available bandwidth, so
//! the server's aggregate throughput approaches its remaining capacity
//! B_i^rem instead of bottlenecking on one doubled-up backup NIC.
//!
//! Forwarding to a non-affinity NIC is PXN-/NUMA-aware via
//! [`Route::auto_forward`]: same-socket NICs are reached over the (freed)
//! PCIe lanes, cross-socket NICs via NVLink proxy (PXN).

use crate::collectives::exec::ChannelRouting;
use crate::collectives::ring::split_even;
use crate::collectives::schedule::{Schedule, SubTransfer, TransferGroup};
use crate::netsim::FaultPlane;
use crate::topology::{NicId, Topology};

/// Rewrite a schedule so that traffic of unusable NICs is redistributed
/// across healthy NICs of the same server, weighted by capacity.
/// Groups untouched by failures are passed through unchanged.
pub fn apply_balance(
    topo: &Topology,
    faults: &FaultPlane,
    routing: &ChannelRouting,
    sched: &Schedule,
) -> Schedule {
    let mut out = Schedule::new(format!("{}+balance", sched.label));
    for g in &sched.groups {
        let mut ng = TransferGroup {
            channel: g.channel,
            deps: g.deps.clone(),
            subs: Vec::with_capacity(g.subs.len()),
            op: g.op,
        };
        for sub in &g.subs {
            let src_server = topo.server_of_gpu(sub.src);
            let dst_server = topo.server_of_gpu(sub.dst);
            if src_server == dst_server {
                ng.subs.push(sub.clone());
                continue;
            }
            let (src_nic, dst_nic) = match sub.nic_hint {
                Some(pair) => pair,
                None => (
                    routing.nic[g.channel][src_server],
                    routing.nic[g.channel][dst_server],
                ),
            };
            if faults.is_usable(src_nic) && faults.is_usable(dst_nic) {
                ng.subs.push(sub.clone());
                continue;
            }
            // Split across healthy NIC pairs, weighted by capacity factor.
            let pairs = healthy_pairs(topo, faults, src_server, dst_server);
            if pairs.is_empty() {
                // No alternate path: leave as-is; the executor will abort.
                ng.subs.push(sub.clone());
                continue;
            }
            let weights: Vec<f64> = pairs
                .iter()
                .map(|&(a, b)| faults.capacity_factor(a).min(faults.capacity_factor(b)))
                .collect();
            let shares = weighted_split(sub.bytes, &weights);
            for (&(a, b), &bytes) in pairs.iter().zip(shares.iter()) {
                if bytes == 0 {
                    continue;
                }
                ng.subs.push(SubTransfer { src: sub.src, dst: sub.dst, bytes, nic_hint: Some((a, b)) });
            }
            if ng.subs.is_empty() {
                // All shares rounded to zero (tiny message): put everything
                // on the best pair.
                ng.subs.push(SubTransfer {
                    src: sub.src,
                    dst: sub.dst,
                    bytes: sub.bytes,
                    nic_hint: Some(pairs[0]),
                });
            }
        }
        out.groups.push(ng);
    }
    out
}

/// Healthy rail-aligned NIC pairs between two servers (same-rail preferred,
/// falling back to cross-rail combination when a rail is dead on only one
/// side).
fn healthy_pairs(
    topo: &Topology,
    faults: &FaultPlane,
    src_server: usize,
    dst_server: usize,
) -> Vec<(NicId, NicId)> {
    let mut pairs = Vec::new();
    let k = topo.cfg.nics_per_server;
    let src_base = src_server * k;
    let dst_base = dst_server * k;
    // Same-rail pairs.
    for r in 0..k {
        let (a, b) = (src_base + r, dst_base + r);
        if faults.is_usable(a) && faults.is_usable(b) {
            pairs.push((a, b));
        }
    }
    if !pairs.is_empty() {
        return pairs;
    }
    // Rail-mismatched fallback: any healthy src NIC to any healthy dst NIC,
    // matched in order.
    let src_ok = faults.healthy_nics(topo, src_server);
    let dst_ok = faults.healthy_nics(topo, dst_server);
    for (a, b) in src_ok.iter().zip(dst_ok.iter()) {
        pairs.push((*a, *b));
    }
    pairs
}

/// Split `total` into integer parts proportional to `weights`, summing
/// exactly to `total`.
pub fn weighted_split(total: u64, weights: &[f64]) -> Vec<u64> {
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return split_even(total, weights.len());
    }
    let mut out: Vec<u64> = weights
        .iter()
        .map(|w| ((total as f64) * w / wsum).floor() as u64)
        .collect();
    let assigned: u64 = out.iter().sum();
    let mut leftover = total - assigned;
    // Hand the remainder to the largest weights first (deterministic).
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap().then(a.cmp(&b)));
    let mut i = 0;
    while leftover > 0 {
        out[order[i % order.len()]] += 1;
        leftover -= 1;
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::{ChannelRouting, ExecOptions, Executor, FaultAction, FaultEvent};
    use crate::collectives::ring::{nccl_rings, ring_allreduce};
    use crate::collectives::PhantomPlane;
    use crate::config::TimingConfig;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    fn setup() -> (Topology, crate::netsim::Engine, FaultPlane) {
        let t = Topology::build(&TopologyConfig::testbed_h100());
        let e = netsim::engine_for(&t);
        let f = FaultPlane::new(&t);
        (t, e, f)
    }

    #[test]
    fn weighted_split_sums_and_proportions() {
        let s = weighted_split(1000, &[1.0, 1.0, 2.0]);
        assert_eq!(s.iter().sum::<u64>(), 1000);
        assert_eq!(s, vec![250, 250, 500]);
        assert_eq!(weighted_split(7, &[0.0, 0.0]), vec![4, 3]);
    }

    #[test]
    fn healthy_schedule_passes_through() {
        let (t, _e, f) = setup();
        let spec = nccl_rings(&t, 4);
        let sched = ring_allreduce(&spec, 1 << 20, 0);
        let routing = ChannelRouting::default_rails(&t, 4);
        let out = apply_balance(&t, &f, &routing, &sched);
        assert_eq!(out.len(), sched.len());
        assert_eq!(out.total_bytes(), sched.total_bytes());
        assert!(out.groups.iter().all(|g| g.subs.len() == 1));
    }

    #[test]
    fn failed_nic_traffic_spreads_across_seven() {
        let (t, mut e, mut f) = setup();
        f.fail_nic(&t, &mut e, 0);
        let spec = nccl_rings(&t, 8);
        let sched = ring_allreduce(&spec, 8 << 20, 0);
        let routing = ChannelRouting::default_rails(&t, 8);
        let out = apply_balance(&t, &f, &routing, &sched);
        out.validate().unwrap();
        assert_eq!(out.total_bytes(), sched.total_bytes());
        // Channel-0 inter-server groups must now have 7 sub-transfers.
        let mut saw_split = false;
        for g in &out.groups {
            if g.channel == 0 && g.subs.len() > 1 {
                saw_split = true;
                assert_eq!(g.subs.len(), 7);
                for s in &g.subs {
                    let (a, b) = s.nic_hint.unwrap();
                    assert!(f.is_usable(a) && f.is_usable(b));
                    assert_ne!(a, 0);
                }
            }
        }
        assert!(saw_split);
    }

    #[test]
    fn balance_beats_hotrepair_on_large_messages() {
        // Fig 15 / Fig 3: Balance ≈ 7/8 of healthy vs HotRepair ≈ 1/2.
        let t = Topology::build(&TopologyConfig::testbed_h100());
        let timing = TimingConfig::default();
        let d: u64 = 1 << 30;
        let spec = nccl_rings(&t, 8);
        let sched = ring_allreduce(&spec, d, 0);
        let routing = ChannelRouting::default_rails(&t, 8);
        // Healthy baseline.
        let base = Executor::new(&t, &timing, routing.clone(), ExecOptions::default(), vec![])
            .run(&sched, &mut PhantomPlane)
            .completion_or_panic();
        // HotRepair: fail NIC 0 right at start.
        let hr = Executor::new(
            &t,
            &timing,
            routing.clone(),
            ExecOptions::default(),
            vec![FaultEvent { at: 1e-6, nic: 0, action: FaultAction::FailNic }],
        )
        .run(&sched, &mut PhantomPlane)
        .completion_or_panic();
        // Balance: schedule rewritten for the known failure.
        let mut eng = netsim::engine_for(&t);
        let mut f = FaultPlane::new(&t);
        f.fail_nic(&t, &mut eng, 0);
        let balanced = apply_balance(&t, &f, &routing, &sched);
        let bal = Executor::new(&t, &timing, routing, ExecOptions::default(), vec![])
            .with_initial_faults(&[(0, FaultAction::FailNic)])
            .run(&balanced, &mut PhantomPlane)
            .completion_or_panic();
        let r_hr = base / hr;
        let r_bal = base / bal;
        assert!(r_bal > r_hr + 0.15, "balance {r_bal:.3} vs hotrepair {r_hr:.3}");
        assert!(r_bal > 0.8, "balance retains {r_bal:.3}");
    }

    #[test]
    fn rail_mismatch_uses_cross_rail_pairs() {
        let (t, mut e, mut f) = setup();
        // Kill rail 0 on server 0 AND rail 0..7 except rail 3 on both ends:
        // force cross-rail pairing by killing all same-rail pairs.
        for r in 0..8 {
            if r != 3 {
                f.fail_nic(&t, &mut e, r); // server 0
            }
            if r != 5 {
                f.fail_nic(&t, &mut e, 8 + r); // server 1
            }
        }
        let pairs = healthy_pairs(&t, &f, 0, 1);
        assert_eq!(pairs, vec![(3, 13)]);
    }
}
