//! Multi-NIC GPU buffer registration (§4.3 Technique I).
//!
//! An RDMA NIC can DMA a GPU buffer only if the buffer was registered with
//! it. Registration is slow (milliseconds per buffer, tens of milliseconds
//! with connection setup), so stock systems register each buffer with one
//! NIC — which blocks failover. R²CCL registers every buffer with *all* of
//! the server's NICs at communicator init, so migration never pays
//! registration on the recovery path. Registration installs IOMMU/MR
//! mapping entries only; no data is duplicated.

use std::collections::HashMap;

use crate::config::TimingConfig;
use crate::topology::{GpuId, NicId, Topology};

/// Registration policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegPolicy {
    /// R²CCL: register with every NIC of the owning server at init.
    MultiNic,
    /// Baseline/ablation: register with the affinity NIC only; failover
    /// pays on-demand registration + connection setup.
    AffinityOnly,
}

/// A registered GPU buffer.
#[derive(Debug, Clone)]
pub struct BufferReg {
    pub gpu: GpuId,
    pub bytes: u64,
    /// NICs that currently hold a memory region for this buffer.
    nics: Vec<NicId>,
}

/// Registration table for all communication buffers of a communicator.
#[derive(Debug, Clone)]
pub struct RegistrationTable {
    policy: RegPolicy,
    buffers: HashMap<u64, BufferReg>,
    next_handle: u64,
    /// Cumulative time spent registering (init-time under MultiNic,
    /// recovery-time under AffinityOnly).
    pub init_cost: f64,
}

impl RegistrationTable {
    pub fn new(policy: RegPolicy) -> Self {
        RegistrationTable {
            policy,
            buffers: HashMap::new(),
            next_handle: 0,
            init_cost: 0.0,
        }
    }

    pub fn policy(&self) -> RegPolicy {
        self.policy
    }

    /// Register a buffer at communicator init; returns its handle.
    /// Under `MultiNic` the buffer is registered with every NIC of the
    /// GPU's server (cost accrues to `init_cost`, off the recovery path).
    pub fn register(
        &mut self,
        topo: &Topology,
        timing: &TimingConfig,
        gpu: GpuId,
        bytes: u64,
    ) -> u64 {
        let handle = self.next_handle;
        self.next_handle += 1;
        let nics: Vec<NicId> = match self.policy {
            RegPolicy::MultiNic => {
                let all: Vec<NicId> = topo.nics_of_server(topo.server_of_gpu(gpu)).collect();
                self.init_cost += timing.lazy_reg_cost * all.len() as f64;
                all
            }
            RegPolicy::AffinityOnly => {
                self.init_cost += timing.lazy_reg_cost;
                vec![topo.affinity_nic(gpu)]
            }
        };
        self.buffers.insert(handle, BufferReg { gpu, bytes, nics });
        handle
    }

    pub fn is_registered(&self, handle: u64, nic: NicId) -> bool {
        self.buffers
            .get(&handle)
            .map(|b| b.nics.contains(&nic))
            .unwrap_or(false)
    }

    /// Recovery-path cost of making `handle` usable from `nic`:
    /// zero when already registered (R²CCL), otherwise on-demand
    /// registration (the ablation's penalty). Registers as a side effect.
    pub fn failover_cost(&mut self, timing: &TimingConfig, handle: u64, nic: NicId) -> f64 {
        let b = self
            .buffers
            .get_mut(&handle)
            .unwrap_or_else(|| panic!("unknown buffer handle {handle}"));
        if b.nics.contains(&nic) {
            0.0
        } else {
            b.nics.push(nic);
            timing.lazy_reg_cost + timing.conn_setup_cost
        }
    }

    pub fn buffer(&self, handle: u64) -> Option<&BufferReg> {
        self.buffers.get(&handle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyConfig;

    fn topo() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn multinic_registers_all_server_nics() {
        let t = topo();
        let timing = TimingConfig::default();
        let mut table = RegistrationTable::new(RegPolicy::MultiNic);
        let h = table.register(&t, &timing, 3, 1 << 20);
        for n in t.nics_of_server(0) {
            assert!(table.is_registered(h, n));
        }
        // Not registered on the other server's NICs.
        assert!(!table.is_registered(h, 8));
    }

    #[test]
    fn multinic_failover_is_free() {
        let t = topo();
        let timing = TimingConfig::default();
        let mut table = RegistrationTable::new(RegPolicy::MultiNic);
        let h = table.register(&t, &timing, 0, 1 << 20);
        assert_eq!(table.failover_cost(&timing, h, 5), 0.0);
    }

    #[test]
    fn affinity_only_pays_on_failover() {
        let t = topo();
        let timing = TimingConfig::default();
        let mut table = RegistrationTable::new(RegPolicy::AffinityOnly);
        let h = table.register(&t, &timing, 0, 1 << 20);
        assert!(table.is_registered(h, 0));
        assert!(!table.is_registered(h, 1));
        let cost = table.failover_cost(&timing, h, 1);
        assert!((cost - (timing.lazy_reg_cost + timing.conn_setup_cost)).abs() < 1e-12);
        // Second failover to the same NIC is then free (now registered).
        assert_eq!(table.failover_cost(&timing, h, 1), 0.0);
    }

    #[test]
    fn init_cost_accrues_off_recovery_path() {
        let t = topo();
        let timing = TimingConfig::default();
        let mut table = RegistrationTable::new(RegPolicy::MultiNic);
        table.register(&t, &timing, 0, 1 << 20);
        assert!((table.init_cost - 8.0 * timing.lazy_reg_cost).abs() < 1e-12);
    }
}
