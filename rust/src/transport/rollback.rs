//! DMA-buffer rollback (§4.3 Technique II).
//!
//! Transfers move in fixed-size chunks; a completion is polled per chunk.
//! On failure the sender rewinds to the first chunk without a completion
//! and the receiver resets to the last confirmed chunk: everything at or
//! beyond the acknowledged prefix is retransmitted on the backup path.
//! Send buffers stay intact until completion (safe to re-read) and receive
//! buffers are not consumed by kernels before completion (safe to
//! overwrite a partial chunk), which is what makes this lossless.

/// Chunk accounting for one in-flight transfer.
#[derive(Debug, Clone)]
pub struct RollbackCursor {
    /// Total transfer size in bytes.
    pub size: u64,
    /// Chunk granularity (completion / rollback quantum).
    pub chunk: u64,
}

impl RollbackCursor {
    pub fn new(size: u64, chunk: u64) -> Self {
        assert!(chunk > 0);
        RollbackCursor { size, chunk }
    }

    /// Number of chunks in the transfer (last one may be short).
    pub fn n_chunks(&self) -> u64 {
        self.size.div_ceil(self.chunk)
    }

    /// The acknowledged prefix after `progress` bytes have physically moved:
    /// only whole chunks have completions, so the prefix is quantised down.
    pub fn acked_bytes(&self, progress: f64) -> u64 {
        let p = progress.clamp(0.0, self.size as f64) as u64;
        if p == self.size {
            // The final (possibly short) chunk has its completion too.
            return self.size;
        }
        let whole = (p / self.chunk) * self.chunk;
        whole.min(self.size)
    }

    /// Bytes that must be retransmitted after a failure at `progress`.
    pub fn retransmit_bytes(&self, progress: f64) -> u64 {
        self.size - self.acked_bytes(progress)
    }

    /// Bytes of wasted (re-sent) work caused by the failure: the partially
    /// transferred chunk that had no completion yet.
    pub fn wasted_bytes(&self, progress: f64) -> u64 {
        let p = progress.clamp(0.0, self.size as f64) as u64;
        p - self.acked_bytes(progress)
    }

    /// Index of the first chunk that must be resent.
    pub fn rollback_chunk(&self, progress: f64) -> u64 {
        self.acked_bytes(progress) / self.chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acked_is_chunk_quantised() {
        let c = RollbackCursor::new(1000, 100);
        assert_eq!(c.acked_bytes(0.0), 0);
        assert_eq!(c.acked_bytes(99.0), 0);
        assert_eq!(c.acked_bytes(100.0), 100);
        assert_eq!(c.acked_bytes(250.0), 200);
        assert_eq!(c.acked_bytes(1000.0), 1000);
    }

    #[test]
    fn retransmit_covers_the_rest() {
        let c = RollbackCursor::new(1000, 100);
        assert_eq!(c.retransmit_bytes(250.0), 800);
        assert_eq!(c.retransmit_bytes(0.0), 1000);
        assert_eq!(c.retransmit_bytes(1000.0), 0);
    }

    #[test]
    fn wasted_is_partial_chunk_only() {
        let c = RollbackCursor::new(1000, 100);
        assert_eq!(c.wasted_bytes(250.0), 50);
        assert_eq!(c.wasted_bytes(300.0), 0);
        assert!(c.wasted_bytes(999.0) < 100);
    }

    #[test]
    fn short_final_chunk() {
        let c = RollbackCursor::new(1050, 100);
        assert_eq!(c.n_chunks(), 11);
        assert_eq!(c.acked_bytes(1049.0), 1000);
        assert_eq!(c.acked_bytes(1050.0), 1050);
        assert_eq!(c.retransmit_bytes(1049.0), 50);
    }

    #[test]
    fn rollback_chunk_index() {
        let c = RollbackCursor::new(1000, 100);
        assert_eq!(c.rollback_chunk(0.0), 0);
        assert_eq!(c.rollback_chunk(350.0), 3);
    }

    #[test]
    fn progress_beyond_size_clamps() {
        let c = RollbackCursor::new(1000, 128);
        assert_eq!(c.acked_bytes(5000.0), 1000);
        assert_eq!(c.retransmit_bytes(5000.0), 0);
    }
}
