//! Connections and the pre-established backup pool.
//!
//! NCCL binds each channel edge to one (GPU, NIC) pair and sets up exactly
//! that RDMA connection; when the NIC dies the edge is unrecoverable
//! without re-initialisation. R²CCL pre-establishes idle "sleep"
//! connections from every GPU to its whole failover chain of NICs at init
//! (§3.1 C1), so a collective can resume on any healthy NIC instantly.

use crate::netsim::FaultPlane;
use crate::topology::{GpuId, NicId, Route, Topology};

/// One (possibly sleeping) RDMA connection between two GPUs over concrete
/// NICs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Connection {
    pub src_gpu: GpuId,
    pub dst_gpu: GpuId,
    pub src_nic: NicId,
    pub dst_nic: NicId,
    /// Pre-established at init (true for every pool entry under R²CCL;
    /// only the primary under the baseline).
    pub established: bool,
}

impl Connection {
    pub fn route(&self, topo: &Topology) -> Route {
        Route::between(topo, self.src_gpu, self.dst_gpu, self.src_nic, self.dst_nic)
    }
}

/// Backup-connection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackupPolicy {
    /// R²CCL: the full failover chain is pre-established.
    PreEstablished,
    /// Baseline: only the primary exists; failover must set up a connection
    /// (tens of milliseconds, §4.3).
    None,
}

/// The connection pool for one inter-server edge (src GPU → dst GPU).
///
/// Entries are ordered by PCIe distance from the *source* GPU (the paper
/// orders the failover chain by PCIe distance and activates the
/// topologically closest healthy NIC). The destination NIC follows the
/// source NIC's rail when possible (rail-optimised fabrics keep same-rail
/// paths one hop); otherwise the destination's own failover order is used.
#[derive(Debug, Clone)]
pub struct EdgePool {
    pub src_gpu: GpuId,
    pub dst_gpu: GpuId,
    entries: Vec<Connection>,
}

impl EdgePool {
    /// Build the pool for an inter-server GPU pair.
    pub fn build(topo: &Topology, src_gpu: GpuId, dst_gpu: GpuId, policy: BackupPolicy) -> EdgePool {
        assert_ne!(
            topo.server_of_gpu(src_gpu),
            topo.server_of_gpu(dst_gpu),
            "edge pools are inter-server"
        );
        let dst_server = topo.server_of_gpu(dst_gpu);
        let dst_chain = topo.failover_chain(dst_gpu);
        let mut entries = Vec::new();
        for (i, &src_nic) in topo.failover_chain(src_gpu).iter().enumerate() {
            // Prefer the same rail on the destination side; when the
            // destination server has no NIC on that rail (fewer NICs than
            // the source's rail index), fall back to the destination GPU's
            // own failover order instead of panicking.
            let rail = topo.rail_of_nic(src_nic);
            let dst_nic = topo
                .nics_of_server(dst_server)
                .nth(rail)
                .unwrap_or(dst_chain[i % dst_chain.len()]);
            entries.push(Connection {
                src_gpu,
                dst_gpu,
                src_nic,
                dst_nic,
                established: match policy {
                    BackupPolicy::PreEstablished => true,
                    BackupPolicy::None => i == 0,
                },
            });
        }
        EdgePool { src_gpu, dst_gpu, entries }
    }

    /// The primary connection (affinity NICs).
    pub fn primary(&self) -> &Connection {
        &self.entries[0]
    }

    pub fn entries(&self) -> &[Connection] {
        &self.entries
    }

    /// First entry whose *both* NICs are usable, skipping `skip` (the failed
    /// connection). Returns `None` when the server has no healthy NIC pair
    /// left (full partition → out of scope, job must fall back to
    /// checkpointing).
    pub fn first_healthy(&self, faults: &FaultPlane, skip: Option<&Connection>) -> Option<&Connection> {
        self.entries.iter().find(|c| {
            faults.is_usable(c.src_nic)
                && faults.is_usable(c.dst_nic)
                && Some(*c) != skip
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim;
    use crate::topology::TopologyConfig;

    fn topo() -> Topology {
        Topology::build(&TopologyConfig::testbed_h100())
    }

    #[test]
    fn pool_primary_is_affinity_pair() {
        let t = topo();
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        assert_eq!(pool.primary().src_nic, 2);
        assert_eq!(pool.primary().dst_nic, 10);
        assert!(pool.primary().established);
        assert_eq!(pool.entries().len(), 8);
    }

    #[test]
    fn pool_is_pcie_distance_ordered() {
        let t = topo();
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        let dists: Vec<u32> = pool
            .entries()
            .iter()
            .map(|c| t.pcie_distance(2, c.src_nic))
            .collect();
        let mut sorted = dists.clone();
        sorted.sort_unstable();
        assert_eq!(dists, sorted);
    }

    #[test]
    fn backup_keeps_rail_alignment() {
        let t = topo();
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        for c in pool.entries() {
            assert_eq!(t.rail_of_nic(c.src_nic), t.rail_of_nic(c.dst_nic));
        }
    }

    #[test]
    fn first_healthy_skips_failed_nic() {
        let t = topo();
        let mut eng = netsim::engine_for(&t);
        let mut fp = FaultPlane::new(&t);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        fp.fail_nic(&t, &mut eng, 2); // primary's src NIC
        let next = pool.first_healthy(&fp, Some(pool.primary())).unwrap();
        assert_ne!(next.src_nic, 2);
        // Closest same-NUMA NIC comes first (0 per failover_chain of GPU 2).
        assert_eq!(next.src_nic, 0);
    }

    #[test]
    fn no_healthy_pair_when_all_nics_down() {
        let t = topo();
        let mut eng = netsim::engine_for(&t);
        let mut fp = FaultPlane::new(&t);
        for n in 0..8 {
            fp.fail_nic(&t, &mut eng, n);
        }
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        assert!(pool.first_healthy(&fp, None).is_none());
    }

    #[test]
    fn baseline_pool_has_single_established_entry() {
        let t = topo();
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::None);
        assert!(pool.primary().established);
        assert!(pool.entries()[1..].iter().all(|c| !c.established));
    }
}
