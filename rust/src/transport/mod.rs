//! Transport layer: connections, multi-NIC registration, DMA rollback and
//! live migration — the "hot repair" half of R²CCL (§4.3).

pub mod connection;
pub mod migration;
pub mod registration;
pub mod rollback;

pub use connection::{BackupPolicy, Connection, EdgePool};
pub use migration::{plan_migration, MigrationError, MigrationPlan};
pub use registration::{RegPolicy, RegistrationTable};
pub use rollback::RollbackCursor;
