//! Live migration of a failed connection (§4.3): pick the topologically
//! closest healthy backup, compute the recovery latency, and the bytes to
//! retransmit from the rollback point.

use crate::config::TimingConfig;
use crate::detect::Diagnosis;
use crate::netsim::FaultPlane;
use crate::topology::Topology;

use super::connection::{Connection, EdgePool};
use super::registration::{RegPolicy, RegistrationTable};
use super::rollback::RollbackCursor;

/// Outcome of planning a migration for one failed transfer.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    /// The backup connection to resume on.
    pub target: Connection,
    /// Wall-clock cost between the fault hitting the wire and the first
    /// retransmitted byte leaving on the backup path.
    pub latency: f64,
    /// Bytes still to send (from the rollback point).
    pub retransmit_bytes: u64,
    /// Bytes of duplicated work caused by the partial chunk.
    pub wasted_bytes: u64,
}

/// Errors that end hot repair and escalate to the job-level fallback.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum MigrationError {
    #[error("no healthy NIC pair remains for edge {src_gpu}->{dst_gpu} (full partition)")]
    NoAlternatePath { src_gpu: usize, dst_gpu: usize },
}

/// Plan the migration of a failed transfer.
///
/// `progress` is the bytes physically moved when the fault hit;
/// `detection_latency` is what the detection layer took to produce the
/// diagnosis (bilateral OOB + triangulation, see [`crate::detect`]).
///
/// The diagnosis drives the recovery shape:
/// * `Transient` (clean probes — a QP-level error, not a component fault):
///   re-arm the queue pair and resume *on the same path*; the rollback
///   cursor still governs where retransmission starts, but no partial
///   chunk was lost on the wire, so nothing is counted as wasted.
/// * `LocalNicFault` / `RemoteNicFault` / `LinkFault`: migrate to the
///   topologically closest healthy backup; bytes past the last acked
///   chunk boundary were cut mid-flight and count as wasted wire work.
#[allow(clippy::too_many_arguments)]
pub fn plan_migration(
    topo: &Topology,
    timing: &TimingConfig,
    faults: &FaultPlane,
    regs: &mut RegistrationTable,
    pool: &EdgePool,
    failed: &Connection,
    cursor: &RollbackCursor,
    progress: f64,
    detection_latency: f64,
    diagnosis: Diagnosis,
) -> Result<MigrationPlan, MigrationError> {
    let same_path_ok = diagnosis == Diagnosis::Transient
        && faults.is_usable(failed.src_nic)
        && faults.is_usable(failed.dst_nic);
    let target = if same_path_ok {
        *failed
    } else {
        pool.first_healthy(faults, Some(failed))
            .copied()
            .ok_or(MigrationError::NoAlternatePath {
                src_gpu: pool.src_gpu,
                dst_gpu: pool.dst_gpu,
            })?
    };
    debug_assert_eq!(
        topo.server_of_nic(target.src_nic),
        topo.server_of_gpu(pool.src_gpu),
        "backup src NIC must live on the source server"
    );
    debug_assert_eq!(
        topo.server_of_nic(target.dst_nic),
        topo.server_of_gpu(pool.dst_gpu),
        "backup dst NIC must live on the destination server"
    );

    // Rollback bookkeeping is constant; registration / connection setup is
    // free iff the buffer was multi-registered and the backup connection
    // pre-established (a transient retry reuses the established pair).
    let mut latency = detection_latency + timing.rollback_cost;
    if !target.established {
        latency += timing.conn_setup_cost;
    }
    if !same_path_ok && regs.policy() == RegPolicy::AffinityOnly {
        // On-demand registration of the send buffer with the backup NIC.
        // (Handle 0 is the channel's staging buffer; the collective engine
        // registers one per channel.)
        latency += timing.lazy_reg_cost;
    }

    Ok(MigrationPlan {
        target,
        latency,
        retransmit_bytes: cursor.retransmit_bytes(progress),
        wasted_bytes: if same_path_ok { 0 } else { cursor.wasted_bytes(progress) },
    })
}

/// Convenience: the steady-state hot-repair latency (multi-reg +
/// pre-established), used by analytic models.
pub fn hot_repair_latency(timing: &TimingConfig) -> f64 {
    timing.hot_repair_latency()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::Diagnosis;
    use crate::netsim;
    use crate::topology::TopologyConfig;
    use crate::transport::connection::BackupPolicy;

    fn setup() -> (Topology, crate::netsim::Engine, FaultPlane, TimingConfig) {
        let t = Topology::build(&TopologyConfig::testbed_h100());
        let eng = netsim::engine_for(&t);
        let fp = FaultPlane::new(&t);
        (t, eng, fp, TimingConfig::default())
    }

    #[test]
    fn migration_resumes_on_closest_healthy_nic() {
        let (t, mut eng, mut fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::MultiNic);
        regs.register(&t, &timing, 2, 1 << 30);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        fp.fail_nic(&t, &mut eng, 2);
        let cursor = RollbackCursor::new(1 << 20, timing.chunk_bytes);
        let plan = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            300_000.0, timing.hot_repair_latency(), Diagnosis::LocalNicFault,
        )
        .unwrap();
        assert_eq!(plan.target.src_nic, 0);
        // Multi-reg + pre-established: recovery stays in low milliseconds.
        assert!(plan.latency < 10.0e-3, "latency={}", plan.latency);
        // 300000 bytes moved, chunk 512KiB → nothing acked yet.
        assert_eq!(plan.retransmit_bytes, 1 << 20);
    }

    #[test]
    fn successive_failover_walks_the_chain() {
        let (t, mut eng, mut fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::MultiNic);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        let cursor = RollbackCursor::new(1 << 20, timing.chunk_bytes);
        fp.fail_nic(&t, &mut eng, 2);
        let p1 = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            0.0, 1e-3, Diagnosis::LocalNicFault,
        )
        .unwrap();
        // Second failure hits the backup too.
        fp.fail_nic(&t, &mut eng, p1.target.src_nic);
        let p2 = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, &p1.target, &cursor,
            0.0, 1e-3, Diagnosis::LocalNicFault,
        )
        .unwrap();
        assert_ne!(p2.target.src_nic, p1.target.src_nic);
        assert_ne!(p2.target.src_nic, 2);
    }

    #[test]
    fn lazy_policy_pays_setup_costs() {
        let (t, mut eng, mut fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::AffinityOnly);
        regs.register(&t, &timing, 2, 1 << 30);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::None);
        fp.fail_nic(&t, &mut eng, 2);
        let cursor = RollbackCursor::new(1 << 20, timing.chunk_bytes);
        let plan = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            0.0, timing.hot_repair_latency(), Diagnosis::LocalNicFault,
        )
        .unwrap();
        // Baseline pays connection setup + registration: ≥ 35ms.
        assert!(plan.latency > 30.0e-3, "latency={}", plan.latency);
    }

    #[test]
    fn transient_diagnosis_retries_same_path() {
        // Clean probes (QP-level error): no migration, no wasted bytes —
        // the established pair is re-armed and resumes from the rollback
        // point.
        let (t, _eng, fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::AffinityOnly);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        let cursor = RollbackCursor::new(4 << 20, timing.chunk_bytes);
        let progress = (timing.chunk_bytes + 1000) as f64; // 1 chunk acked
        let plan = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            progress, 1e-3, Diagnosis::Transient,
        )
        .unwrap();
        assert_eq!(plan.target, *pool.primary(), "transient must stay on the same path");
        assert_eq!(plan.wasted_bytes, 0);
        assert_eq!(plan.retransmit_bytes, (4 << 20) - timing.chunk_bytes);
        // No lazy-registration penalty either: the buffer is already
        // registered with the NIC we keep using.
        assert!(plan.latency < 5.0e-3, "latency={}", plan.latency);
    }

    #[test]
    fn transient_on_dead_nic_still_migrates() {
        // A Transient diagnosis can race a real failure (the fault hit
        // between probe and plan): if the path is unusable, migrate anyway.
        let (t, mut eng, mut fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::MultiNic);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        fp.fail_nic(&t, &mut eng, 2);
        let cursor = RollbackCursor::new(1 << 20, timing.chunk_bytes);
        let plan = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            0.0, 1e-3, Diagnosis::Transient,
        )
        .unwrap();
        assert_ne!(plan.target.src_nic, 2);
    }

    #[test]
    fn full_partition_escalates() {
        let (t, mut eng, mut fp, timing) = setup();
        let mut regs = RegistrationTable::new(RegPolicy::MultiNic);
        let pool = EdgePool::build(&t, 2, 10, BackupPolicy::PreEstablished);
        for n in 0..8 {
            fp.fail_nic(&t, &mut eng, n);
        }
        let cursor = RollbackCursor::new(1 << 20, timing.chunk_bytes);
        let err = plan_migration(
            &t, &timing, &fp, &mut regs, &pool, pool.primary(), &cursor,
            0.0, 1e-3, Diagnosis::LocalNicFault,
        )
        .unwrap_err();
        assert!(matches!(err, MigrationError::NoAlternatePath { .. }));
    }
}
