//! Job-recovery baselines: checkpoint/restart and fast failover, compared
//! arm-by-arm against R²CCL's lossless in-flight failover and its
//! elastic-membership shrink discipline.
//!
//! The paper's headline claim is not that faults are rare but that the
//! *recovery discipline* determines their cost: a conventional job reacts
//! to an unrecoverable fault by detecting it (minutes), isolating the bad
//! node, reloading the last periodic checkpoint (losing every iteration
//! since), and re-initialising the communicator — a cost that grows with
//! cluster size. FFTrainer-style fast failover shrinks that pipeline with
//! just-in-time checkpoints and Mnemosyne-style communication-free
//! communicator re-init; R²CCL removes it entirely by migrating in-flight
//! collectives around the fault, and its elastic membership layer shrinks
//! the communicator past whole-server deaths instead of restarting. This
//! module prices all four disciplines against the *same* deterministic
//! fault script and reports the difference as wasted GPU-hours.
//!
//! * [`config`] — [`RecoveryConfig`]: checkpoint interval/stall, rollback
//!   pipeline stages, fast-failover stage costs; JSON round-trips exactly.
//! * [`arms`] — [`compare_arms`]: the pure analytic overlay that replays a
//!   finished [`crate::scenario::ScenarioReport`] under each baseline and
//!   emits the [`RecoveryCompare`] block scenario reports serialize.
//! * [`sweep`] — [`recovery_sweep`]: every corpus scenario under all four
//!   arms, backing the `recovery-compare` CLI subcommand and
//!   `bench_results/recovery_compare.json`.

pub mod arms;
pub mod config;
pub mod sweep;

pub use arms::{compare_arms, ArmOutcome, RecoveryCompare};
pub use config::RecoveryConfig;
pub use sweep::{recovery_sweep, recovery_sweep_to_json, RecoverySweepRow};
