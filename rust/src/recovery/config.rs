//! Knobs of the job-recovery baseline arms.
//!
//! Training-side costs are expressed in *healthy-iteration units* — the
//! same time base the scenario event scripts use — so one config is
//! meaningful across workloads whose absolute iteration times differ by
//! orders of magnitude; the arm evaluator converts to seconds through the
//! report's `healthy_iter_time`. The request-serving knob
//! (`fast_restart_s`) is in seconds, matching that workload's time base.
//!
//! Defaults follow the paper's §2.2 recovery-pipeline shape (detection and
//! isolation dominate, reload next, communicator rebuild scaling with the
//! cluster) scaled down to scenario-sized horizons, with the fast-failover
//! arm anchored on FFTrainer's "almost-free state management" and
//! Mnemosyne's communication-free communicator re-initialization.

use crate::util::Json;

/// Configuration of the checkpoint/restart and fast-failover arms.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryConfig {
    /// Periodic checkpoint cadence: a checkpoint is written after every
    /// `checkpoint_interval` completed iterations (≥ 1).
    pub checkpoint_interval: usize,
    /// Stall charged to the iteration that writes a periodic checkpoint
    /// (iteration units).
    pub checkpoint_stall: f64,
    /// Fault detection + isolation before a whole-job restart (iteration
    /// units) — the §2.2 "3–30 min detect, 9–14 min isolate" stages.
    pub detect: f64,
    /// Checkpoint reload at restart (iteration units).
    pub restore: f64,
    /// Communicator re-initialization at restart: fixed base cost
    /// (iteration units)…
    pub reinit_base: f64,
    /// …plus a per-server term (iteration units × n_servers): NCCL-style
    /// bootstrap all-gathers grow with the cluster.
    pub reinit_per_server: f64,
    /// AdapCC exclusion-path reconfiguration cost when a boundary fault is
    /// survivable (iteration units).
    pub exclusion_reconfigure: f64,
    /// Fast-failover steady-state tax per iteration (fraction) — the
    /// in-memory state-management overhead FFTrainer reports as almost
    /// free.
    pub fast_steady_overhead: f64,
    /// Fault-signal detection before the just-in-time checkpoint
    /// (iteration units).
    pub fast_detect: f64,
    /// Just-in-time checkpoint written on the fault signal (iteration
    /// units) — no rollback, so no lost iterations.
    pub jit_checkpoint_stall: f64,
    /// State restore from the in-memory JIT checkpoint (iteration units).
    pub fast_restore: f64,
    /// Mnemosyne-style communication-free communicator re-init (iteration
    /// units, deliberately *not* scaled by n_servers).
    pub fast_reinit: f64,
    /// Request-serving fast-failover replica reconnection (seconds).
    pub fast_restart_s: f64,
    /// Elastic-shrink arm: one communicator shrink/expand/promotion —
    /// re-rank survivors and rebuild the TP/PP/DP groups (iteration
    /// units). Charged once per whole-server incident and once per
    /// expand-back, matching the single epoch bump each transition costs.
    pub elastic_reconfigure: f64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            checkpoint_interval: 10,
            checkpoint_stall: 0.25,
            detect: 20.0,
            restore: 30.0,
            reinit_base: 5.0,
            reinit_per_server: 0.25,
            exclusion_reconfigure: 2.0,
            fast_steady_overhead: 0.01,
            fast_detect: 0.5,
            jit_checkpoint_stall: 0.25,
            fast_restore: 0.5,
            fast_reinit: 0.25,
            fast_restart_s: 0.25,
            elastic_reconfigure: 1.0,
        }
    }
}

impl RecoveryConfig {
    /// Reject configs the arm evaluator cannot interpret. Mirrors the
    /// clean-error contract of every other scenario-file field.
    pub fn validate(&self) -> Result<(), String> {
        if self.checkpoint_interval < 1 {
            return Err("recovery: checkpoint_interval must be >= 1".to_string());
        }
        for (name, v) in [
            ("checkpoint_stall", self.checkpoint_stall),
            ("detect", self.detect),
            ("restore", self.restore),
            ("reinit_base", self.reinit_base),
            ("reinit_per_server", self.reinit_per_server),
            ("exclusion_reconfigure", self.exclusion_reconfigure),
            ("fast_steady_overhead", self.fast_steady_overhead),
            ("fast_detect", self.fast_detect),
            ("jit_checkpoint_stall", self.jit_checkpoint_stall),
            ("fast_restore", self.fast_restore),
            ("fast_reinit", self.fast_reinit),
            ("fast_restart_s", self.fast_restart_s),
            ("elastic_reconfigure", self.elastic_reconfigure),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("recovery: {name} must be finite and >= 0"));
            }
        }
        if self.fast_steady_overhead >= 1.0 {
            return Err("recovery: fast_steady_overhead must be < 1".to_string());
        }
        Ok(())
    }

    /// Deterministic serialization; [`RecoveryConfig::from_json`] is its
    /// exact inverse (property-tested in `rust/tests/prop_recovery.rs`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("checkpoint_interval", self.checkpoint_interval)
            .set("checkpoint_stall", self.checkpoint_stall)
            .set("detect", self.detect)
            .set("restore", self.restore)
            .set("reinit_base", self.reinit_base)
            .set("reinit_per_server", self.reinit_per_server)
            .set("exclusion_reconfigure", self.exclusion_reconfigure)
            .set("fast_steady_overhead", self.fast_steady_overhead)
            .set("fast_detect", self.fast_detect)
            .set("jit_checkpoint_stall", self.jit_checkpoint_stall)
            .set("fast_restore", self.fast_restore)
            .set("fast_reinit", self.fast_reinit)
            .set("fast_restart_s", self.fast_restart_s)
            .set("elastic_reconfigure", self.elastic_reconfigure)
    }

    /// Parse from a scenario file's `"recovery"` block; every omitted field
    /// takes its [`Default`] value, so `{"checkpoint_interval": 4}` is a
    /// complete config.
    pub fn from_json(j: &Json) -> Result<RecoveryConfig, String> {
        let d = RecoveryConfig::default();
        let f = |k: &str, dv: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dv);
        let cfg = RecoveryConfig {
            checkpoint_interval: j
                .get("checkpoint_interval")
                .and_then(Json::as_usize)
                .unwrap_or(d.checkpoint_interval),
            checkpoint_stall: f("checkpoint_stall", d.checkpoint_stall),
            detect: f("detect", d.detect),
            restore: f("restore", d.restore),
            reinit_base: f("reinit_base", d.reinit_base),
            reinit_per_server: f("reinit_per_server", d.reinit_per_server),
            exclusion_reconfigure: f("exclusion_reconfigure", d.exclusion_reconfigure),
            fast_steady_overhead: f("fast_steady_overhead", d.fast_steady_overhead),
            fast_detect: f("fast_detect", d.fast_detect),
            jit_checkpoint_stall: f("jit_checkpoint_stall", d.jit_checkpoint_stall),
            fast_restore: f("fast_restore", d.fast_restore),
            fast_reinit: f("fast_reinit", d.fast_reinit),
            fast_restart_s: f("fast_restart_s", d.fast_restart_s),
            elastic_reconfigure: f("elastic_reconfigure", d.elastic_reconfigure),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RecoveryConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_zero_interval_and_negative_times() {
        let mut c = RecoveryConfig::default();
        c.checkpoint_interval = 0;
        assert!(c.validate().unwrap_err().contains("checkpoint_interval"));
        let mut c = RecoveryConfig::default();
        c.detect = -1.0;
        assert!(c.validate().unwrap_err().contains("detect"));
        let mut c = RecoveryConfig::default();
        c.fast_steady_overhead = 1.0;
        assert!(c.validate().unwrap_err().contains("fast_steady_overhead"));
        let mut c = RecoveryConfig::default();
        c.restore = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn partial_json_fills_defaults() {
        let j = Json::parse(r#"{"checkpoint_interval": 4, "detect": 2.5}"#).unwrap();
        let c = RecoveryConfig::from_json(&j).unwrap();
        assert_eq!(c.checkpoint_interval, 4);
        assert_eq!(c.detect, 2.5);
        assert_eq!(c.restore, RecoveryConfig::default().restore);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let c = RecoveryConfig {
            checkpoint_interval: 3,
            checkpoint_stall: 0.1 + 0.2, // deliberately non-representable
            detect: 19.75,
            restore: 31.5,
            reinit_base: 4.125,
            reinit_per_server: 1.0 / 3.0,
            exclusion_reconfigure: 2.5,
            fast_steady_overhead: 0.0125,
            fast_detect: 0.75,
            jit_checkpoint_stall: 0.3,
            fast_restore: 0.6,
            fast_reinit: 0.2,
            fast_restart_s: 0.125,
            elastic_reconfigure: 0.875,
        };
        let s = c.to_json().pretty();
        let back = RecoveryConfig::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(c, back, "f64 fields must survive the JSON round-trip bit-exactly");
    }
}
