//! The four-arm recovery comparison: R²CCL lossless failover vs R²CCL
//! elastic shrink vs checkpoint/restart vs FFTrainer-style fast failover.
//!
//! [`compare_arms`] is a *pure analytic overlay* over a finished
//! [`ScenarioReport`]: it replays the scenario's compiled fault script
//! against behavioural models of the two baseline recovery disciplines and
//! the elastic-membership discipline, and reads the lossless arm straight
//! off the report. Nothing is re-simulated, so the overlay is
//! deterministic, cheap enough to run for every corpus scenario, and
//! re-evaluable against one report under different [`RecoveryConfig`]s
//! (which is what the checkpoint-interval monotonicity properties in
//! `rust/tests/prop_recovery.rs` do).
//!
//! Baseline fate rules, per the paper's §2.1/§8.2–8.3 characterisation:
//!
//! * **checkpoint/restart (training)** — AdapCC heartbeats tax every
//!   collective; a fault striking mid-collective (always, for fractional
//!   event times; by seeded draw on boundary events) crashes the job, which
//!   rolls back to the last periodic checkpoint and pays detection + reload
//!   + a communicator re-init that scales with `n_servers`. Boundary faults
//!   in a pure-DP layout can instead take AdapCC's exclusion path,
//!   shrinking compute capacity until repair. A job restart re-provisions
//!   hardware (failed units are replaced), so standing faults do not
//!   re-crash every subsequent iteration.
//! * **fast failover (training)** — FFTrainer's just-in-time checkpoint on
//!   the fault signal: a small steady state-management tax, and per fault a
//!   short detect + JIT-checkpoint + restore + Mnemosyne-style
//!   communication-free re-init, with zero lost iterations and spare
//!   swap-in (no capacity loss).
//! * **DejaVu (serving)** — continuous KV replication taxes every decode
//!   step; a fault restarts the worker and pays fetch + recompute of the
//!   non-replicated tail.
//! * **elastic shrink (R²CCL)** — the membership discipline of this repo's
//!   runner: single-NIC faults are absorbed losslessly (no membership
//!   change), a whole-server death shrinks the communicator once
//!   ([`RecoveryConfig::elastic_reconfigure`] plus the in-flight fraction
//!   retried), excluded servers cost capacity until repair expands them
//!   back, and the arm only crashes when the scenario's quorum is lost.
//!
//! Both baseline arms run over the *same* degraded network as the lossless
//! run, so their per-iteration slowdown is never allowed below the measured
//! lossless overhead of that iteration — which makes "lossless never wastes
//! more than checkpoint/restart" a structural guarantee, not a tuning
//! accident.

use std::collections::BTreeMap;

use crate::baselines::{AdapCcModel, DejaVuModel};
use crate::collectives::exec::FaultAction;
use crate::config::Preset;
use crate::fabric::{SwitchAction, SwitchTarget};
use crate::scenario::{FaultScenario, ScenarioEvent, ScenarioReport, SwitchScenarioEvent, Workload};
use crate::sim::inference::{kv_shard_bytes, InferModel};
use crate::sim::training::scenario_collectives_per_iteration;
use crate::util::{Json, Rng};

use super::RecoveryConfig;

/// Floor for degrade factors so a pathological `Degrade(0)` cannot divide
/// by zero in the bottleneck model.
const MIN_FACTOR: f64 = 1e-3;

/// Seed perturbation for the baseline-fate RNG stream, so arm fate draws
/// never alias the scenario compiler's own stream.
const FATE_STREAM: u64 = 0xa5e1_c0de_5eed_0001;

/// One recovery discipline's end-to-end outcome on a scenario. Times are
/// in seconds of simulated wall clock; `lost_iterations` is in workload
/// iteration units (training iterations or served requests).
#[derive(Debug, Clone, PartialEq)]
pub struct ArmOutcome {
    pub arm: &'static str,
    pub total_time: f64,
    pub useful_time: f64,
    pub wasted_time: f64,
    /// The headline metric: wasted GPU-hours over the whole cluster.
    pub gpu_hours_wasted: f64,
    /// Whole-job (or worker) restarts paid. For the elastic arm this
    /// counts membership reconfigurations (shrinks + expands) instead —
    /// elastic recovery never restarts the job.
    pub restarts: usize,
    /// Checkpoints written (periodic for the restart arm, just-in-time for
    /// the fast arm).
    pub checkpoints: usize,
    /// Work rolled back and re-executed (checkpoint arm) or permanently
    /// lost (crashed lossless runs).
    pub lost_iterations: f64,
    pub crashed: bool,
}

impl ArmOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("arm", self.arm)
            .set("total_time", self.total_time)
            .set("useful_time", self.useful_time)
            .set("wasted_time", self.wasted_time)
            .set("gpu_hours_wasted", self.gpu_hours_wasted)
            .set("restarts", self.restarts)
            .set("checkpoints", self.checkpoints)
            .set("lost_iterations", self.lost_iterations)
            .set("crashed", self.crashed)
    }
}

/// The four arms side by side, plus the paper-style speedup ratios
/// (baseline wasted time over lossless wasted time). Speedups are `None`
/// (JSON `null`) when the lossless arm crashed or wasted effectively
/// nothing — a ratio against ~0 carries no information.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryCompare {
    pub n_gpus: usize,
    pub lossless: ArmOutcome,
    pub elastic: ArmOutcome,
    pub checkpoint: ArmOutcome,
    pub fast: ArmOutcome,
    pub speedup_vs_checkpoint: Option<f64>,
    pub speedup_vs_fast: Option<f64>,
    pub speedup_vs_elastic: Option<f64>,
}

impl RecoveryCompare {
    fn new(
        n_gpus: usize,
        lossless: ArmOutcome,
        elastic: ArmOutcome,
        checkpoint: ArmOutcome,
        fast: ArmOutcome,
    ) -> Self {
        let speedup = |arm: &ArmOutcome| {
            (!lossless.crashed && lossless.wasted_time > 1e-9)
                .then(|| arm.wasted_time / lossless.wasted_time)
        };
        let speedup_vs_checkpoint = speedup(&checkpoint);
        let speedup_vs_fast = speedup(&fast);
        let speedup_vs_elastic = speedup(&elastic);
        RecoveryCompare {
            n_gpus,
            lossless,
            elastic,
            checkpoint,
            fast,
            speedup_vs_checkpoint,
            speedup_vs_fast,
            speedup_vs_elastic,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) => Json::from(x),
            None => Json::Null,
        };
        Json::obj()
            .set("n_gpus", self.n_gpus)
            .set("lossless", self.lossless.to_json())
            .set("elastic_shrink", self.elastic.to_json())
            .set("checkpoint_restart", self.checkpoint.to_json())
            .set("fast_failover", self.fast.to_json())
            .set("speedup_vs_checkpoint", opt(self.speedup_vs_checkpoint))
            .set("speedup_vs_fast", opt(self.speedup_vs_fast))
            .set("speedup_vs_elastic", opt(self.speedup_vs_elastic))
    }
}

/// Evaluate all three recovery arms for a finished scenario run. `preset`
/// must be the *effective* preset the report was produced on (see
/// [`crate::scenario::runner::effective_preset`]).
pub fn compare_arms(
    scenario: &FaultScenario,
    report: &ScenarioReport,
    preset: &Preset,
    cfg: &RecoveryConfig,
) -> RecoveryCompare {
    let n_gpus = preset.topo.n_servers * preset.topo.gpus_per_server;
    let (lossless, elastic, checkpoint, fast) = match &scenario.workload {
        Workload::Training { tp, dp, pp, .. } => (
            lossless_iteration_arm(scenario, report, n_gpus),
            replay_elastic(scenario, report, preset, cfg, n_gpus),
            replay_training(false, scenario, report, preset, cfg, *tp, *dp, *pp, n_gpus),
            replay_training(true, scenario, report, preset, cfg, *tp, *dp, *pp, n_gpus),
        ),
        Workload::Serving { prompt_tokens } => (
            lossless_iteration_arm(scenario, report, n_gpus),
            replay_elastic(scenario, report, preset, cfg, n_gpus),
            replay_serving(false, scenario, report, preset, cfg, *prompt_tokens, n_gpus),
            replay_serving(true, scenario, report, preset, cfg, *prompt_tokens, n_gpus),
        ),
        Workload::RequestServing { prompt_tokens, max_batch, .. } => {
            request_arms(report, preset, cfg, *prompt_tokens, *max_batch, n_gpus)
        }
    };
    RecoveryCompare::new(n_gpus, lossless, elastic, checkpoint, fast)
}

fn gpu_hours(wasted_s: f64, n_gpus: usize) -> f64 {
    wasted_s * n_gpus as f64 / 3600.0
}

/// The R²CCL arm of an iteration-loop workload, read straight off the
/// report: everything beyond `completed × healthy_iter_time` is overhead
/// the lossless failover paid (migrations, retransmissions, degraded
/// paths). No checkpoints, no restarts; lost work only if the run crashed
/// (path genuinely gone — outside every recovery discipline's scope).
fn lossless_iteration_arm(
    scenario: &FaultScenario,
    report: &ScenarioReport,
    n_gpus: usize,
) -> ArmOutcome {
    let h = report.healthy_iter_time.max(1e-12);
    let completed = report.iterations.iter().filter(|r| !r.crashed).count();
    let useful = completed as f64 * h;
    let wasted = (report.total_time - useful).max(0.0);
    ArmOutcome {
        arm: "lossless",
        total_time: report.total_time,
        useful_time: useful,
        wasted_time: wasted,
        gpu_hours_wasted: gpu_hours(wasted, n_gpus),
        restarts: 0,
        checkpoints: 0,
        lost_iterations: if report.crashed {
            scenario.iters.saturating_sub(completed) as f64
        } else {
            0.0
        },
        crashed: report.crashed,
    }
}

/// Measured lossless overhead of iteration `k` (fraction of the healthy
/// iteration), the floor under both baselines' per-iteration slowdown:
/// the baselines cross the same degraded network without R²CCL's
/// rebalancing, so they can never beat the lossless run on a shared link.
fn lossless_overhead_at(report: &ScenarioReport, k: usize, h: f64) -> f64 {
    report
        .iterations
        .get(k)
        .filter(|r| !r.crashed)
        .map(|r| ((r.time - h) / h).max(0.0))
        .unwrap_or(0.0)
}

/// Standing degrade state shared by the baseline replays: per-NIC factors,
/// NIC liveness, and switch-tier factors keyed by target.
struct DegradeState {
    nic_up: Vec<bool>,
    nic_factor: Vec<f64>,
    switch_factor: BTreeMap<(u8, usize, usize), f64>,
}

impl DegradeState {
    fn new(total_nics: usize) -> Self {
        DegradeState {
            nic_up: vec![true; total_nics],
            nic_factor: vec![1.0; total_nics],
            switch_factor: BTreeMap::new(),
        }
    }

    /// Global bottleneck factor: the worst standing degradation across live
    /// NICs and switch elements (1.0 when pristine).
    fn bottleneck(&self) -> f64 {
        let min_nic = self
            .nic_factor
            .iter()
            .zip(&self.nic_up)
            .filter(|(_, up)| **up)
            .map(|(f, _)| *f)
            .fold(1.0, f64::min);
        let min_sw = self.switch_factor.values().copied().fold(1.0, f64::min);
        min_nic.min(min_sw).max(MIN_FACTOR)
    }

    fn repair_nic(&mut self, nic: usize, failed_units: &mut usize) {
        if !self.nic_up[nic] {
            self.nic_up[nic] = true;
            *failed_units = (*failed_units).saturating_sub(1);
        }
        self.nic_factor[nic] = 1.0;
    }

    fn apply_switch(&mut self, e: &SwitchScenarioEvent) {
        let key = e.target.sort_key();
        match e.action {
            // A dead uplink stalls its pinned flows until ECMP re-pins:
            // modeled as a standing half-capacity bottleneck (coarse —
            // baselines have no per-flow migration to do better).
            SwitchAction::Down => {
                self.switch_factor.insert(key, 0.5);
            }
            SwitchAction::Up => {
                self.switch_factor.remove(&key);
            }
            SwitchAction::Degrade(f) => {
                if f >= 1.0 {
                    self.switch_factor.remove(&key);
                } else {
                    self.switch_factor.insert(key, f.max(MIN_FACTOR));
                }
            }
        }
    }

    /// Whole-job restart: the scheduler re-provisions onto healthy spare
    /// hardware, so standing faults and degradations are left behind.
    fn reset(&mut self, failed_units: &mut usize) {
        self.nic_up.iter_mut().for_each(|u| *u = true);
        self.nic_factor.iter_mut().for_each(|f| *f = 1.0);
        self.switch_factor.clear();
        *failed_units = 0;
    }
}

/// Consume every NIC event sharing `t` starting at `*ei`, updating state;
/// returns how many *live* NICs the instant took down (the incident size —
/// a replica outage or multi-NIC fault at one timestamp is ONE incident,
/// not sixteen).
fn coalesce_incident(
    events: &[ScenarioEvent],
    ei: &mut usize,
    t: f64,
    state: &mut DegradeState,
    failed_units: &mut usize,
) -> usize {
    let mut down = 0usize;
    while *ei < events.len() && events[*ei].at_iter == t {
        let ev = events[*ei];
        *ei += 1;
        match ev.action {
            FaultAction::FailNic | FaultAction::CutCable => {
                if state.nic_up[ev.nic] {
                    state.nic_up[ev.nic] = false;
                    down += 1;
                }
            }
            FaultAction::Repair => state.repair_nic(ev.nic, failed_units),
            FaultAction::Degrade(f) => state.nic_factor[ev.nic] = f.max(MIN_FACTOR),
        }
    }
    down
}

/// Consume every switch event sharing `t` starting at `*si`: degrades and
/// repairs update the standing state, leaf outages are *counted* instead
/// of applied. A leaf-down at the instant of a NIC incident is part of the
/// SAME incident — the dying ToR is what took its member NICs down, so
/// billing the leaf event as a second rollback would double-charge one
/// physical fault.
fn coalesce_switch_instant(
    sw: &[SwitchScenarioEvent],
    si: &mut usize,
    t: f64,
    state: &mut DegradeState,
) -> usize {
    let mut leaf_downs = 0usize;
    while *si < sw.len() && sw[*si].at_iter == t {
        let e = sw[*si];
        *si += 1;
        if matches!((e.target, e.action), (SwitchTarget::Leaf(_), SwitchAction::Down)) {
            leaf_downs += 1;
        } else {
            state.apply_switch(&e);
        }
    }
    leaf_downs
}

/// Per-restart downtime of the two baseline disciplines, in iteration
/// units. The checkpoint pipeline's re-init scales with the cluster; the
/// fast arm's Mnemosyne-style re-init deliberately does not.
fn restart_downtime(cfg: &RecoveryConfig, fast: bool, n_servers: usize) -> f64 {
    if fast {
        cfg.fast_detect + cfg.jit_checkpoint_stall + cfg.fast_restore + cfg.fast_reinit
    } else {
        cfg.detect + cfg.restore + cfg.reinit_base + cfg.reinit_per_server * n_servers as f64
    }
}

/// Replay the compiled fault script under a baseline training discipline.
/// `fast = false` is checkpoint/restart with AdapCC behaviour; `fast =
/// true` is the FFTrainer-style fast-failover arm. All bookkeeping is in
/// iteration units, converted to seconds through `healthy_iter_time` at
/// the end.
#[allow(clippy::too_many_arguments)]
fn replay_training(
    fast: bool,
    scenario: &FaultScenario,
    report: &ScenarioReport,
    preset: &Preset,
    cfg: &RecoveryConfig,
    tp: usize,
    dp: usize,
    pp: usize,
    n_gpus: usize,
) -> ArmOutcome {
    let topo = &preset.topo;
    let n_servers = topo.n_servers;
    let h = report.healthy_iter_time.max(1e-12);
    let adapcc = AdapCcModel::default();
    let dp_only = adapcc.supports(tp, pp);
    let steady = if fast {
        cfg.fast_steady_overhead
    } else {
        adapcc.steady_overhead(scenario_collectives_per_iteration(tp, dp, pp)) / h
    };
    let interval = cfg.checkpoint_interval as f64;
    let mut rng = Rng::new(scenario.seed ^ FATE_STREAM);

    let mut state = DegradeState::new(n_servers * topo.nics_per_server);
    let mut failed_units = 0usize;
    let mut wasted = 0.0f64; // iteration units
    let mut lost = 0.0f64;
    let mut restarts = 0usize;
    let mut checkpoints = 0usize;

    let events = &report.events;
    let sw = &report.switch_events;
    let (mut ei, mut si) = (0usize, 0usize);

    for k in 0..scenario.iters {
        let lim = (k + 1) as f64;
        loop {
            let nic_due = ei < events.len() && events[ei].at_iter < lim;
            let sw_due = si < sw.len() && sw[si].at_iter < lim;
            let take_switch = match (nic_due, sw_due) {
                (false, false) => break,
                (true, true) => sw[si].at_iter < events[ei].at_iter,
                (false, true) => true,
                (true, false) => false,
            };
            // A fatal instant: roll back (checkpoint arm) or JIT-failover
            // (fast arm).
            let fatal_at = |t: f64,
                               wasted: &mut f64,
                               lost: &mut f64,
                               restarts: &mut usize,
                               checkpoints: &mut usize,
                               state: &mut DegradeState,
                               failed_units: &mut usize| {
                if fast {
                    *wasted += restart_downtime(cfg, true, n_servers);
                    *checkpoints += 1; // the just-in-time checkpoint
                } else {
                    let lost_now = t - (t / interval).floor() * interval;
                    *lost += lost_now;
                    *wasted += lost_now + restart_downtime(cfg, false, n_servers);
                }
                *restarts += 1;
                state.reset(failed_units);
            };
            if take_switch {
                let e = sw[si];
                if matches!((e.target, e.action), (SwitchTarget::Leaf(_), SwitchAction::Down)) {
                    // A ToR outage severs a whole rail of the pod at once:
                    // fatal for any discipline without in-flight failover.
                    // Every switch event sharing the instant (including
                    // further leaf outages) is the same incident. NIC
                    // events never share it here: ties route to the NIC
                    // branch below, which consumes the leaf events itself.
                    coalesce_switch_instant(sw, &mut si, e.at_iter, &mut state);
                    fatal_at(
                        e.at_iter,
                        &mut wasted,
                        &mut lost,
                        &mut restarts,
                        &mut checkpoints,
                        &mut state,
                        &mut failed_units,
                    );
                } else {
                    si += 1;
                    state.apply_switch(&e);
                }
            } else {
                let e = events[ei];
                match e.action {
                    FaultAction::Repair => {
                        ei += 1;
                        state.repair_nic(e.nic, &mut failed_units);
                    }
                    FaultAction::Degrade(f) => {
                        ei += 1;
                        state.nic_factor[e.nic] = f.max(MIN_FACTOR);
                    }
                    FaultAction::FailNic | FaultAction::CutCable => {
                        let t = e.at_iter;
                        let down =
                            coalesce_incident(events, &mut ei, t, &mut state, &mut failed_units);
                        let leaf_downs = coalesce_switch_instant(sw, &mut si, t, &mut state);
                        if down == 0 && leaf_downs == 0 {
                            continue;
                        }
                        if fast || leaf_downs > 0 {
                            fatal_at(
                                t,
                                &mut wasted,
                                &mut lost,
                                &mut restarts,
                                &mut checkpoints,
                                &mut state,
                                &mut failed_units,
                            );
                        } else {
                            // Fractional times struck inside the collective
                            // window by construction; boundary faults in a
                            // pure-DP layout draw their fate.
                            let crash = !dp_only
                                || t.fract() != 0.0
                                || adapcc.fault_lands_mid_collective(&mut rng);
                            if crash {
                                fatal_at(
                                    t,
                                    &mut wasted,
                                    &mut lost,
                                    &mut restarts,
                                    &mut checkpoints,
                                    &mut state,
                                    &mut failed_units,
                                );
                            } else {
                                // AdapCC exclusion: reconfigure, shrink
                                // capacity until repair or restart.
                                failed_units += down;
                                wasted += cfg.exclusion_reconfigure;
                                if adapcc.capacity_factor(n_gpus, failed_units) <= 0.0 {
                                    fatal_at(
                                        t,
                                        &mut wasted,
                                        &mut lost,
                                        &mut restarts,
                                        &mut checkpoints,
                                        &mut state,
                                        &mut failed_units,
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
        // Accrue iteration k: bottleneck-degrade slowdown (floored by the
        // measured lossless overhead — same network, no rebalancing) plus
        // the arm's steady tax.
        let capacity = if fast {
            1.0 // spares swap in; no exclusion shrinkage
        } else {
            adapcc.capacity_factor(n_gpus, failed_units).max(1.0 / n_gpus.max(1) as f64)
        };
        let model_over = 1.0 / state.bottleneck() / capacity - 1.0;
        wasted += model_over.max(lossless_overhead_at(report, k, h)) + steady;
    }
    if !fast {
        checkpoints = scenario.iters / cfg.checkpoint_interval;
        wasted += checkpoints as f64 * cfg.checkpoint_stall;
    }

    let useful = scenario.iters as f64 * h;
    let wasted_s = wasted * h;
    ArmOutcome {
        arm: if fast { "fast_failover" } else { "checkpoint_restart" },
        total_time: useful + wasted_s,
        useful_time: useful,
        wasted_time: wasted_s,
        gpu_hours_wasted: gpu_hours(wasted_s, n_gpus),
        restarts,
        checkpoints,
        lost_iterations: lost,
        crashed: false,
    }
}

/// Replay the fault script under a serving baseline: DejaVu-style KV
/// replication + worker restart (`fast = false`), or fast failover with a
/// near-free replica reconnection (`fast = true`). One "iteration" is one
/// request's prefill + KV shipment; units as in [`replay_training`].
#[allow(clippy::too_many_arguments)]
fn replay_serving(
    fast: bool,
    scenario: &FaultScenario,
    report: &ScenarioReport,
    preset: &Preset,
    cfg: &RecoveryConfig,
    prompt_tokens: usize,
    n_gpus: usize,
) -> ArmOutcome {
    let topo = &preset.topo;
    let h = report.healthy_iter_time.max(1e-12);
    let dv = DejaVuModel::default();
    let model = InferModel::llama70b();
    let kv = kv_shard_bytes(&model, prompt_tokens) as f64;
    let steady = if fast { cfg.fast_steady_overhead } else { dv.replication_slowdown - 1.0 };

    let mut state = DegradeState::new(topo.n_servers * topo.nics_per_server);
    let mut failed_units = 0usize; // unused shrinkage channel; repairs need it
    let mut wasted = 0.0f64;
    let mut lost = 0.0f64;
    let mut restarts = 0usize;

    let events = &report.events;
    let sw = &report.switch_events;
    let (mut ei, mut si) = (0usize, 0usize);

    for k in 0..scenario.iters {
        let lim = (k + 1) as f64;
        loop {
            let nic_due = ei < events.len() && events[ei].at_iter < lim;
            let sw_due = si < sw.len() && sw[si].at_iter < lim;
            let take_switch = match (nic_due, sw_due) {
                (false, false) => break,
                (true, true) => sw[si].at_iter < events[ei].at_iter,
                (false, true) => true,
                (true, false) => false,
            };
            let incident_at = |t: f64,
                                   wasted: &mut f64,
                                   lost: &mut f64,
                                   restarts: &mut usize,
                                   state: &mut DegradeState,
                                   failed_units: &mut usize| {
                if fast {
                    *wasted += cfg.fast_restart_s / h;
                } else {
                    // Worker restart + KV fetch + recompute of the
                    // non-replicated tail; the in-flight request's
                    // non-replicated progress is lost and redone.
                    *wasted += dv.recovery_time(kv, prompt_tokens, 1.0 / model.prefill_tps) / h;
                    let lost_now = (1.0 - dv.replicated_fraction) * t.fract();
                    *lost += lost_now;
                    *wasted += lost_now;
                }
                *restarts += 1;
                state.reset(failed_units);
            };
            if take_switch {
                let e = sw[si];
                if matches!((e.target, e.action), (SwitchTarget::Leaf(_), SwitchAction::Down)) {
                    // Same-instant switch events are one incident; NIC ties
                    // route to the branch below, which consumes leaf events.
                    coalesce_switch_instant(sw, &mut si, e.at_iter, &mut state);
                    incident_at(
                        e.at_iter,
                        &mut wasted,
                        &mut lost,
                        &mut restarts,
                        &mut state,
                        &mut failed_units,
                    );
                } else {
                    si += 1;
                    state.apply_switch(&e);
                }
            } else {
                let e = events[ei];
                match e.action {
                    FaultAction::Repair => {
                        ei += 1;
                        state.repair_nic(e.nic, &mut failed_units);
                    }
                    FaultAction::Degrade(f) => {
                        ei += 1;
                        state.nic_factor[e.nic] = f.max(MIN_FACTOR);
                    }
                    FaultAction::FailNic | FaultAction::CutCable => {
                        let t = e.at_iter;
                        let down =
                            coalesce_incident(events, &mut ei, t, &mut state, &mut failed_units);
                        let leaf_downs = coalesce_switch_instant(sw, &mut si, t, &mut state);
                        if down > 0 || leaf_downs > 0 {
                            incident_at(
                                t,
                                &mut wasted,
                                &mut lost,
                                &mut restarts,
                                &mut state,
                                &mut failed_units,
                            );
                        }
                    }
                }
            }
        }
        let model_over = 1.0 / state.bottleneck() - 1.0;
        wasted += model_over.max(lossless_overhead_at(report, k, h)) + steady;
    }

    let useful = scenario.iters as f64 * h;
    let wasted_s = wasted * h;
    ArmOutcome {
        arm: if fast { "fast_failover" } else { "checkpoint_restart" },
        total_time: useful + wasted_s,
        useful_time: useful,
        wasted_time: wasted_s,
        gpu_hours_wasted: gpu_hours(wasted_s, n_gpus),
        restarts,
        checkpoints: 0,
        lost_iterations: lost,
        crashed: false,
    }
}

/// The R²CCL elastic-membership arm. Unlike the baselines it keeps the
/// lossless library underneath — single-NIC faults cost exactly what the
/// measured lossless run paid — and adds the membership discipline on top:
/// a fatal instant that leaves whole servers with no live NIC shrinks the
/// communicator once (one [`RecoveryConfig::elastic_reconfigure`] plus the
/// in-flight fraction of the interrupted iteration, retried), excluded
/// servers cost DP-shrunk capacity until a repair expands them back in
/// (another reconfigure), and the arm crashes only when fewer than the
/// scenario's quorum of servers remain — the same invariant the elastic
/// runner enforces.
fn replay_elastic(
    scenario: &FaultScenario,
    report: &ScenarioReport,
    preset: &Preset,
    cfg: &RecoveryConfig,
    n_gpus: usize,
) -> ArmOutcome {
    let topo = &preset.topo;
    let n_servers = topo.n_servers;
    let nics_per = topo.nics_per_server;
    let h = report.healthy_iter_time.max(1e-12);
    let quorum_needed =
        ((scenario.quorum_frac() * n_servers as f64).ceil() as usize).max(1);

    let mut state = DegradeState::new(n_servers * nics_per);
    let mut failed_units = 0usize;
    let mut excluded = vec![false; n_servers];
    let mut wasted = 0.0f64; // iteration units
    let mut reconfigs = 0usize;
    let mut crashed = false;
    let mut completed = scenario.iters;

    let events = &report.events;
    let sw = &report.switch_events;
    let (mut ei, mut si) = (0usize, 0usize);

    'iters: for k in 0..scenario.iters {
        let lim = (k + 1) as f64;
        loop {
            let nic_due = ei < events.len() && events[ei].at_iter < lim;
            let sw_due = si < sw.len() && sw[si].at_iter < lim;
            let take_switch = match (nic_due, sw_due) {
                (false, false) => break,
                (true, true) => sw[si].at_iter < events[ei].at_iter,
                (false, true) => true,
                (true, false) => false,
            };
            if take_switch {
                // Leaf outages are rerouted across the surviving rails by
                // the lossless layer; the measured per-iteration floor
                // already carries that cost, so they neither degrade the
                // standing state nor change membership here.
                let t = sw[si].at_iter;
                coalesce_switch_instant(sw, &mut si, t, &mut state);
            } else {
                let e = events[ei];
                match e.action {
                    FaultAction::Repair => {
                        ei += 1;
                        state.repair_nic(e.nic, &mut failed_units);
                        let s = e.nic / nics_per;
                        if excluded[s] {
                            // The server is reachable again: expand it back
                            // into the job — one more epoch bump.
                            excluded[s] = false;
                            wasted += cfg.elastic_reconfigure;
                            reconfigs += 1;
                        }
                    }
                    FaultAction::Degrade(f) => {
                        ei += 1;
                        state.nic_factor[e.nic] = f.max(MIN_FACTOR);
                    }
                    FaultAction::FailNic | FaultAction::CutCable => {
                        let t = e.at_iter;
                        let down =
                            coalesce_incident(events, &mut ei, t, &mut state, &mut failed_units);
                        coalesce_switch_instant(sw, &mut si, t, &mut state);
                        if down == 0 {
                            continue;
                        }
                        let newly = (0..n_servers)
                            .filter(|&s| {
                                !excluded[s]
                                    && state.nic_up[s * nics_per..(s + 1) * nics_per]
                                        .iter()
                                        .all(|up| !up)
                            })
                            .collect::<Vec<_>>();
                        if newly.is_empty() {
                            // Partial-NIC fault: the lossless layer migrates
                            // flows in place, no membership change.
                            continue;
                        }
                        newly.iter().for_each(|&s| excluded[s] = true);
                        let live = n_servers - excluded.iter().filter(|x| **x).count();
                        if live < quorum_needed {
                            crashed = true;
                            completed = k;
                            break 'iters;
                        }
                        // One shrink per incident (the epoch bumps once no
                        // matter how many servers the instant took), plus
                        // the interrupted iteration's in-flight fraction,
                        // which is retried on the shrunk world.
                        wasted += cfg.elastic_reconfigure + t.fract();
                        reconfigs += 1;
                    }
                }
            }
        }
        // Accrue iteration k: the measured lossless overhead (the library
        // underneath IS the lossless one) plus the DP-shrink capacity loss
        // of any currently excluded servers.
        let capacity =
            (n_servers - excluded.iter().filter(|x| **x).count()) as f64 / n_servers as f64;
        wasted += lossless_overhead_at(report, k, h) + (1.0 / capacity.max(MIN_FACTOR) - 1.0);
    }

    let useful = completed as f64 * h;
    let wasted_s = wasted * h;
    ArmOutcome {
        arm: "elastic_shrink",
        total_time: useful + wasted_s,
        useful_time: useful,
        wasted_time: wasted_s,
        gpu_hours_wasted: gpu_hours(wasted_s, n_gpus),
        restarts: reconfigs,
        checkpoints: 0,
        lost_iterations: (scenario.iters - completed) as f64,
        crashed,
    }
}

/// Count fault incidents (distinct fatal instants) in a compiled script:
/// every same-timestamp group of fresh NIC failures and/or leaf outages is
/// one incident.
fn count_incidents(
    events: &[ScenarioEvent],
    sw: &[SwitchScenarioEvent],
    total_nics: usize,
) -> usize {
    let mut state = DegradeState::new(total_nics);
    let mut failed_units = 0usize;
    let mut incidents = 0usize;
    let (mut ei, mut si) = (0usize, 0usize);
    loop {
        let nic_due = ei < events.len();
        let sw_due = si < sw.len();
        let take_switch = match (nic_due, sw_due) {
            (false, false) => break,
            (true, true) => sw[si].at_iter < events[ei].at_iter,
            (false, true) => true,
            (true, false) => false,
        };
        if take_switch {
            let e = sw[si];
            if matches!((e.target, e.action), (SwitchTarget::Leaf(_), SwitchAction::Down)) {
                // NIC events cannot share the instant here (ties route to
                // the NIC branch), so the leaf group alone is the incident.
                coalesce_switch_instant(sw, &mut si, e.at_iter, &mut state);
                incidents += 1;
            } else {
                si += 1;
                state.apply_switch(&e);
            }
        } else {
            let e = events[ei];
            match e.action {
                FaultAction::Repair => {
                    ei += 1;
                    state.repair_nic(e.nic, &mut failed_units);
                }
                FaultAction::Degrade(_) => ei += 1,
                FaultAction::FailNic | FaultAction::CutCable => {
                    let t = e.at_iter;
                    let down =
                        coalesce_incident(events, &mut ei, t, &mut state, &mut failed_units);
                    let leaf_downs = coalesce_switch_instant(sw, &mut si, t, &mut state);
                    if down > 0 || leaf_downs > 0 {
                        incidents += 1;
                    }
                }
            }
        }
    }
    incidents
}

/// The four arms of a request-serving scenario, all in seconds (that
/// workload's native time base). The lossless arm's waste is the engine
/// ledger's discarded compute; the elastic arm adds one communicator
/// reconfiguration (replica retirement/adoption) per incident on top of
/// it; the DejaVu arm pays the replication tax over the whole window plus
/// one worker recovery per incident; the fast arm pays a near-free replica
/// reconnection per incident.
fn request_arms(
    report: &ScenarioReport,
    preset: &Preset,
    cfg: &RecoveryConfig,
    prompt_tokens: usize,
    max_batch: usize,
    n_gpus: usize,
) -> (ArmOutcome, ArmOutcome, ArmOutcome, ArmOutcome) {
    let model = InferModel::llama70b();
    let dv = DejaVuModel::default();
    let window = report.total_time;
    let (lossless_wasted, lost_requests) = match &report.serving {
        Some(s) => (
            s.ledger.wasted_compute_s(model.decode_step / max_batch.max(1) as f64),
            s.ledger.lost as f64,
        ),
        None => (0.0, 0.0),
    };
    let lossless = ArmOutcome {
        arm: "lossless",
        total_time: window,
        useful_time: (window - lossless_wasted).max(0.0),
        wasted_time: lossless_wasted,
        gpu_hours_wasted: gpu_hours(lossless_wasted, n_gpus),
        restarts: 0,
        checkpoints: 0,
        lost_iterations: lost_requests,
        crashed: report.crashed,
    };
    let incidents = count_incidents(
        &report.events,
        &report.switch_events,
        preset.topo.n_servers * preset.topo.nics_per_server,
    );
    // Elastic: the router already absorbs the loss; membership just pays
    // one epoch bump per incident, converted to seconds through the
    // healthy TTFT (the report's iteration-unit time base).
    let elastic_wasted =
        lossless_wasted + incidents as f64 * cfg.elastic_reconfigure * report.healthy_iter_time;
    let elastic = ArmOutcome {
        arm: "elastic_shrink",
        total_time: window + elastic_wasted,
        useful_time: window,
        wasted_time: elastic_wasted,
        gpu_hours_wasted: gpu_hours(elastic_wasted, n_gpus),
        restarts: incidents,
        checkpoints: 0,
        lost_iterations: 0.0,
        crashed: false,
    };
    // The whole decode batch's KV shards are in flight on a dying replica.
    let kv = kv_shard_bytes(&model, prompt_tokens) as f64 * max_batch.max(1) as f64;
    // Every discipline re-runs the compute the dead replica was holding —
    // the router's ledgered waste is common to all three arms; the
    // baselines pay their replication/restart costs on top. This keeps
    // "lossless never wastes more than a baseline" structural for request
    // serving too.
    let dv_wasted = lossless_wasted
        + (dv.replication_slowdown - 1.0) * window
        + incidents as f64 * dv.recovery_time(kv, prompt_tokens, 1.0 / model.prefill_tps);
    let checkpoint = ArmOutcome {
        arm: "checkpoint_restart",
        total_time: window + dv_wasted,
        useful_time: window,
        wasted_time: dv_wasted,
        gpu_hours_wasted: gpu_hours(dv_wasted, n_gpus),
        restarts: incidents,
        checkpoints: 0,
        lost_iterations: 0.0,
        crashed: false,
    };
    let fast_wasted = lossless_wasted
        + cfg.fast_steady_overhead * window
        + incidents as f64 * cfg.fast_restart_s;
    let fast = ArmOutcome {
        arm: "fast_failover",
        total_time: window + fast_wasted,
        useful_time: window,
        wasted_time: fast_wasted,
        gpu_hours_wasted: gpu_hours(fast_wasted, n_gpus),
        restarts: incidents,
        checkpoints: 0,
        lost_iterations: 0.0,
        crashed: false,
    };
    (lossless, elastic, checkpoint, fast)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{FaultPattern, IterationRecord};

    fn training_scenario(iters: usize, at: f64, seed: u64) -> FaultScenario {
        FaultScenario {
            name: "arms-unit".into(),
            seed,
            iters,
            workload: Workload::Training { tp: 1, dp: 16, pp: 1, bytes_per_rank: 1 << 20 },
            max_overhead: None,
            cluster: None,
            recovery: Some(RecoveryConfig::default()),
            quorum: None,
            telemetry: false,
            patterns: vec![FaultPattern::OneShot {
                at,
                nic: 0,
                action: FaultAction::FailNic,
            }],
        }
    }

    fn synthetic_report(
        events: Vec<ScenarioEvent>,
        iters: usize,
        h: f64,
        extra: f64,
    ) -> ScenarioReport {
        let iterations: Vec<IterationRecord> = (0..iters)
            .map(|k| IterationRecord {
                iter: k,
                // Put the whole lossless overhead on the first iteration.
                time: if k == 0 { h + extra } else { h },
                strategy: "Standard".into(),
                migrations: 0,
                retransmitted_bytes: 0,
                wasted_bytes: 0,
                wire_bytes: 0,
                crashed: false,
                lossless: Some(true),
                trace: Vec::new(),
                events_popped: 0,
                domains_touched: 0,
                resident_resources: 0,
            })
            .collect();
        ScenarioReport {
            scenario: "arms-unit".into(),
            seed: 1,
            events,
            switch_events: Vec::new(),
            healthy_iter_time: h,
            time_base: h,
            iterations,
            total_time: iters as f64 * h + extra,
            goodput: 1.0,
            overhead: extra / (iters as f64).max(1.0),
            migrations: 0,
            retransmitted_bytes: 0,
            wasted_bytes: 0,
            wire_bytes: 0,
            crashed: false,
            path_lost: false,
            lossless: true,
            max_overhead: None,
            serving: None,
            recovery: None,
            elastic: None,
            gray_events: Vec::new(),
            telemetry: None,
            events_popped: 0,
            domains_touched: 0,
            resident_resources: 0,
        }
    }

    fn fail_at(t: f64, nic: usize) -> ScenarioEvent {
        ScenarioEvent { at_iter: t, nic, action: FaultAction::FailNic }
    }

    #[test]
    fn mid_flight_fault_rolls_back_to_last_checkpoint() {
        let sc = training_scenario(8, 6.5, 3);
        let report = synthetic_report(vec![fail_at(6.5, 0)], 8, 1.0, 0.2);
        let cfg = RecoveryConfig { checkpoint_interval: 4, ..RecoveryConfig::default() };
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &cfg);
        // Fractional time ⇒ the checkpoint arm always crashes: loses
        // 6.5 − 4 = 2.5 iterations, restarts once, wrote 8/4 = 2 periodic
        // checkpoints.
        assert_eq!(cmp.checkpoint.restarts, 1);
        assert_eq!(cmp.checkpoint.checkpoints, 2);
        assert!((cmp.checkpoint.lost_iterations - 2.5).abs() < 1e-9);
        assert!(!cmp.checkpoint.crashed);
        // The fast arm loses nothing and pays only the short JIT pipeline.
        assert_eq!(cmp.fast.restarts, 1);
        assert_eq!(cmp.fast.lost_iterations, 0.0);
        assert!(cmp.fast.wasted_time < cmp.checkpoint.wasted_time);
        // Lossless read off the report: 0.2 s of migration overhead.
        assert!((cmp.lossless.wasted_time - 0.2).abs() < 1e-9);
        assert_eq!(cmp.lossless.restarts, 0);
        // Fault-heavy training: the paper-shaped ordering holds with a
        // wide margin.
        let speedup = cmp.speedup_vs_checkpoint.unwrap();
        assert!(speedup > 10.0, "lossless-vs-checkpoint speedup {speedup}");
        assert!(cmp.lossless.wasted_time <= cmp.fast.wasted_time);
        // GPU-hours follow wasted seconds × cluster size.
        let expect = cmp.checkpoint.wasted_time * cmp.n_gpus as f64 / 3600.0;
        assert!((cmp.checkpoint.gpu_hours_wasted - expect).abs() < 1e-12);
    }

    #[test]
    fn compare_arms_is_deterministic() {
        let sc = training_scenario(8, 3.0, 41);
        let report = synthetic_report(vec![fail_at(3.0, 0)], 8, 1.0, 0.1);
        let cfg = RecoveryConfig::default();
        let a = compare_arms(&sc, &report, &Preset::testbed(), &cfg);
        let b = compare_arms(&sc, &report, &Preset::testbed(), &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn boundary_faults_draw_crash_or_exclusion_fates() {
        // Over many seeds, a boundary fault in a pure-DP layout must take
        // both the crash path (rollback ⇒ lost work) and the exclusion
        // path (no restart, reconfigure + capacity slowdown only).
        let report = synthetic_report(vec![fail_at(3.0, 0)], 8, 1.0, 0.0);
        let cfg = RecoveryConfig::default();
        let (mut crashes, mut exclusions) = (0, 0);
        for seed in 0..64 {
            let sc = training_scenario(8, 3.0, seed);
            let cmp = compare_arms(&sc, &report, &Preset::testbed(), &cfg);
            match cmp.checkpoint.restarts {
                1 => {
                    crashes += 1;
                    assert!(cmp.checkpoint.lost_iterations > 0.0);
                }
                0 => {
                    exclusions += 1;
                    assert_eq!(cmp.checkpoint.lost_iterations, 0.0);
                    // Exclusion still costs: reconfigure + degraded
                    // capacity for the remaining iterations.
                    assert!(cmp.checkpoint.wasted_time > 0.0);
                }
                n => panic!("unexpected restart count {n}"),
            }
            // The fast arm's fate never depends on the draw.
            assert_eq!(cmp.fast.restarts, 1);
        }
        assert!(crashes > 0 && exclusions > 0, "{crashes} crashes / {exclusions} exclusions");
    }

    #[test]
    fn tp_layouts_always_crash_the_checkpoint_arm() {
        let mut sc = training_scenario(4, 2.0, 5);
        sc.workload = Workload::Training { tp: 8, dp: 2, pp: 1, bytes_per_rank: 1 << 20 };
        let report = synthetic_report(vec![fail_at(2.0, 0)], 4, 1.0, 0.0);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        // Removing a rank violates TP partitioning: no exclusion path.
        assert_eq!(cmp.checkpoint.restarts, 1);
        assert!(cmp.checkpoint.lost_iterations > 0.0);
    }

    #[test]
    fn simultaneous_failures_coalesce_into_one_incident() {
        let sc = training_scenario(6, 2.5, 9);
        let events = vec![fail_at(2.5, 0), fail_at(2.5, 1), fail_at(2.5, 2)];
        let report = synthetic_report(events, 6, 1.0, 0.0);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert_eq!(cmp.checkpoint.restarts, 1, "one instant ⇒ one rollback");
        assert_eq!(cmp.fast.restarts, 1, "one instant ⇒ one failover");
    }

    #[test]
    fn leaf_down_with_member_nic_failures_bills_one_incident() {
        // A dying ToR takes its member NICs down at the same instant; the
        // merged script carries both the switch event and the NIC events.
        // That is ONE physical fault ⇒ one rollback, not two.
        let sc = training_scenario(6, 2.5, 9);
        let events = vec![fail_at(2.5, 0), fail_at(2.5, 1)];
        let mut report = synthetic_report(events, 6, 1.0, 0.0);
        report.switch_events = vec![SwitchScenarioEvent {
            at_iter: 2.5,
            target: SwitchTarget::Leaf(0),
            action: SwitchAction::Down,
        }];
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert_eq!(cmp.checkpoint.restarts, 1, "leaf + member NICs ⇒ one rollback");
        assert_eq!(cmp.fast.restarts, 1, "leaf + member NICs ⇒ one failover");
        assert_eq!(count_incidents(&report.events, &report.switch_events, 16), 1);
        // A leaf outage at a *different* instant is its own incident again.
        report.switch_events.push(SwitchScenarioEvent {
            at_iter: 4.5,
            target: SwitchTarget::Leaf(1),
            action: SwitchAction::Down,
        });
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert_eq!(cmp.fast.restarts, 2);
        assert_eq!(count_incidents(&report.events, &report.switch_events, 16), 2);
    }

    #[test]
    fn elastic_arm_shrinks_past_a_server_death_cheaper_than_a_rollback() {
        // Every NIC of testbed server 0 dies at one fractional instant: the
        // checkpoint arm rolls back and re-provisions; the elastic arm pays
        // one reconfigure + the in-flight fraction, then runs DP-shrunk.
        let sc = training_scenario(8, 2.5, 7);
        let events: Vec<ScenarioEvent> = (0..8).map(|n| fail_at(2.5, n)).collect();
        let report = synthetic_report(events, 8, 1.0, 0.0);
        let cfg = RecoveryConfig::default();
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &cfg);
        assert_eq!(cmp.elastic.arm, "elastic_shrink");
        assert!(!cmp.elastic.crashed);
        assert_eq!(cmp.elastic.restarts, 1, "one shrink; the server never repairs");
        assert_eq!(cmp.elastic.checkpoints, 0);
        assert_eq!(cmp.elastic.lost_iterations, 0.0, "retried, not lost");
        // reconfigure (1.0) + in-flight (0.5) + 6 half-capacity iterations.
        assert!((cmp.elastic.wasted_time - 7.5).abs() < 1e-9, "{}", cmp.elastic.wasted_time);
        assert!(cmp.elastic.wasted_time < cmp.checkpoint.wasted_time);
        assert!(cmp.speedup_vs_elastic.is_none(), "lossless report wasted nothing");
    }

    #[test]
    fn elastic_arm_expands_back_when_the_dead_server_repairs() {
        let sc = training_scenario(8, 2.5, 7);
        let mut events: Vec<ScenarioEvent> = (0..8).map(|n| fail_at(2.5, n)).collect();
        events.push(ScenarioEvent { at_iter: 4.5, nic: 0, action: FaultAction::Repair });
        let report = synthetic_report(events, 8, 1.0, 0.0);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        // Shrink at 2.5, expand at 4.5: two reconfigurations, and only
        // iterations 2 and 3 run at half capacity.
        assert_eq!(cmp.elastic.restarts, 2);
        assert!((cmp.elastic.wasted_time - (1.0 + 0.5 + 2.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn elastic_arm_crashes_only_on_quorum_loss() {
        // Both testbed servers die: no quorum (default 0.5 ⇒ 1 of 2), so
        // even the elastic discipline has nothing left to shrink onto.
        let sc = training_scenario(8, 2.5, 7);
        let events: Vec<ScenarioEvent> = (0..16).map(|n| fail_at(2.5, n)).collect();
        let report = synthetic_report(events, 8, 1.0, 0.0);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert!(cmp.elastic.crashed);
        assert_eq!(cmp.elastic.lost_iterations, 6.0, "iterations 2..8 never ran");
        // Tightening the quorum to "everyone" makes even a one-server loss
        // fatal for the elastic arm.
        let mut sc1 = training_scenario(8, 2.5, 7);
        sc1.quorum = Some(1.0);
        let events1: Vec<ScenarioEvent> = (0..8).map(|n| fail_at(2.5, n)).collect();
        let report1 = synthetic_report(events1, 8, 1.0, 0.0);
        let cmp1 = compare_arms(&sc1, &report1, &Preset::testbed(), &RecoveryConfig::default());
        assert!(cmp1.elastic.crashed);
    }

    #[test]
    fn elastic_arm_ignores_partial_nic_faults_beyond_the_lossless_floor() {
        // One NIC of eight dies: the lossless layer migrates in place, so
        // the elastic arm pays exactly the measured lossless overhead — no
        // reconfiguration, no capacity loss.
        let sc = training_scenario(8, 2.5, 7);
        let report = synthetic_report(vec![fail_at(2.5, 0)], 8, 1.0, 0.3);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert_eq!(cmp.elastic.restarts, 0);
        assert!(!cmp.elastic.crashed);
        assert!((cmp.elastic.wasted_time - 0.3).abs() < 1e-9, "{}", cmp.elastic.wasted_time);
        assert!((cmp.elastic.wasted_time - cmp.lossless.wasted_time).abs() < 1e-9);
    }

    #[test]
    fn healthy_scenario_reports_null_speedups() {
        let sc = FaultScenario { patterns: vec![], ..training_scenario(4, 0.0, 1) };
        let report = synthetic_report(vec![], 4, 1.0, 0.0);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert_eq!(cmp.speedup_vs_checkpoint, None, "no waste to compare against");
        assert_eq!(cmp.speedup_vs_fast, None);
        assert_eq!(cmp.speedup_vs_elastic, None);
        // The baselines still pay their steady taxes.
        assert!(cmp.checkpoint.wasted_time > 0.0);
        assert!(cmp.fast.wasted_time > 0.0);
        let j = cmp.to_json().pretty();
        assert!(j.contains("\"speedup_vs_checkpoint\": null"), "{j}");
        assert!(j.contains("\"gpu_hours_wasted\""));
    }

    #[test]
    fn baseline_slowdown_never_beats_the_measured_lossless_run() {
        // A degrade-only scenario: the lossless report shows 30% overhead
        // on iteration 0; the baselines cross the same network, so their
        // wasted time must be at least that.
        let mut sc = training_scenario(4, 0.0, 2);
        sc.patterns = vec![];
        let report = synthetic_report(vec![], 4, 1.0, 0.3);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        assert!((cmp.lossless.wasted_time - 0.3).abs() < 1e-9);
        assert!(cmp.checkpoint.wasted_time >= cmp.lossless.wasted_time);
        assert!(cmp.fast.wasted_time >= cmp.lossless.wasted_time);
    }

    #[test]
    fn repair_restores_capacity_for_later_iterations() {
        // Fail at a boundary then repair two iterations later: whatever the
        // drawn fate, by the end the degrade state is clean, so wasted time
        // is strictly less than the same scenario without the repair.
        let with_repair = synthetic_report(
            vec![
                fail_at(2.0, 0),
                ScenarioEvent { at_iter: 4.0, nic: 0, action: FaultAction::Repair },
            ],
            12,
            1.0,
            0.0,
        );
        let without = synthetic_report(vec![fail_at(2.0, 0)], 12, 1.0, 0.0);
        // Seed chosen per-iteration fate draws identical across the two
        // reports (same scenario/seed, same single draw).
        let sc = training_scenario(12, 2.0, 13);
        let cfg = RecoveryConfig::default();
        let a = compare_arms(&sc, &with_repair, &Preset::testbed(), &cfg);
        let b = compare_arms(&sc, &without, &Preset::testbed(), &cfg);
        assert!(a.checkpoint.wasted_time <= b.checkpoint.wasted_time);
    }

    #[test]
    fn serving_arm_charges_dejavu_restart() {
        let sc = FaultScenario {
            name: "serve-arms".into(),
            seed: 2,
            iters: 4,
            workload: Workload::Serving { prompt_tokens: 2000 },
            max_overhead: None,
            cluster: None,
            recovery: Some(RecoveryConfig::default()),
            quorum: None,
            telemetry: false,
            patterns: vec![FaultPattern::OneShot {
                at: 1.5,
                nic: 1,
                action: FaultAction::FailNic,
            }],
        };
        // One serving "iteration" ≈ 0.15 s of prefill + KV shipment.
        let report = synthetic_report(vec![fail_at(1.5, 1)], 4, 0.15, 0.01);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        // DejaVu recovery is worker-restart dominated: ≥ 12 s wasted on a
        // ~0.6 s window dwarfs the lossless migration.
        assert!(cmp.checkpoint.wasted_time >= DejaVuModel::default().worker_restart);
        assert_eq!(cmp.checkpoint.restarts, 1);
        assert!(cmp.checkpoint.lost_iterations > 0.0, "non-replicated tail is redone");
        assert!(cmp.fast.wasted_time < cmp.checkpoint.wasted_time);
        let speedup = cmp.speedup_vs_checkpoint.unwrap();
        assert!(speedup > 10.0, "serving restart speedup {speedup}");
    }

    #[test]
    fn incident_counting_coalesces_and_tracks_liveness() {
        let events = vec![
            // One instant, three NICs: one incident.
            fail_at(0.5, 0),
            fail_at(0.5, 1),
            fail_at(0.5, 2),
            // Re-failing a dead NIC: not an incident.
            fail_at(0.8, 1),
            // Repair then re-fail: a fresh incident.
            ScenarioEvent { at_iter: 1.0, nic: 0, action: FaultAction::Repair },
            fail_at(1.5, 0),
        ];
        assert_eq!(count_incidents(&events, &[], 16), 2);
    }

    #[test]
    fn arm_json_carries_all_fields() {
        let sc = training_scenario(8, 6.5, 3);
        let report = synthetic_report(vec![fail_at(6.5, 0)], 8, 1.0, 0.2);
        let cmp = compare_arms(&sc, &report, &Preset::testbed(), &RecoveryConfig::default());
        let j = cmp.to_json().pretty();
        for key in [
            "\"n_gpus\"",
            "\"lossless\"",
            "\"elastic_shrink\"",
            "\"checkpoint_restart\"",
            "\"fast_failover\"",
            "\"speedup_vs_checkpoint\"",
            "\"speedup_vs_fast\"",
            "\"speedup_vs_elastic\"",
            "\"wasted_time\"",
            "\"gpu_hours_wasted\"",
            "\"lost_iterations\"",
            "\"restarts\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // JSON round-trips through the parser (numbers stay numbers).
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("n_gpus").and_then(Json::as_usize), Some(cmp.n_gpus));
    }
}
