//! Corpus-wide recovery sweep: every scenario in a corpus evaluated under
//! all four recovery arms, regardless of whether the scenario file asked
//! for a `recovery` block.
//!
//! This is the data source of the `recovery-compare` CLI subcommand and
//! the `recovery_compare` bench, which writes
//! `bench_results/recovery_compare.json`. Scenarios that *do* carry a
//! `recovery` block are swept with their own config; all others use
//! [`RecoveryConfig::default`] — so the sweep covers the whole corpus
//! while golden traces stay gated on the explicit opt-in.

use crate::config::Preset;
use crate::scenario::{effective_preset, FaultScenario, ScenarioRunner};
use crate::util::Json;

use super::{compare_arms, RecoveryCompare, RecoveryConfig};

/// One corpus scenario's four-arm outcome.
#[derive(Debug, Clone)]
pub struct RecoverySweepRow {
    pub scenario: String,
    pub compare: RecoveryCompare,
}

impl RecoverySweepRow {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("compare", self.compare.to_json())
    }
}

/// Run every scenario and overlay the four recovery arms on its report.
/// Rows come back in input order; the whole sweep is deterministic at any
/// thread count (each run is independent and the overlay is seeded from
/// the scenario).
pub fn recovery_sweep(
    scenarios: &[FaultScenario],
    preset: &Preset,
    threads: usize,
) -> Vec<RecoverySweepRow> {
    crate::util::par::parallel_map(scenarios, threads, |sc| {
        let eff = effective_preset(sc, preset);
        let report = ScenarioRunner::new(sc, preset).run();
        let cfg = sc.recovery.clone().unwrap_or_default();
        RecoverySweepRow {
            scenario: sc.name.clone(),
            compare: compare_arms(sc, &report, &eff, &cfg),
        }
    })
}

/// Deterministic serialization of a sweep — the schema of
/// `bench_results/recovery_compare.json` (see `bench_results/README.md`).
pub fn recovery_sweep_to_json(rows: &[RecoverySweepRow]) -> Json {
    let mut arr = Json::arr();
    for r in rows {
        arr.push(r.to_json());
    }
    Json::obj().set("scenarios", arr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::FaultAction;
    use crate::scenario::{FaultPattern, Workload};

    fn corpus() -> Vec<FaultScenario> {
        vec![
            FaultScenario {
                name: "sweep-a".into(),
                seed: 11,
                iters: 4,
                workload: Workload::Training { tp: 1, dp: 16, pp: 1, bytes_per_rank: 1 << 22 },
                max_overhead: None,
                cluster: None,
                recovery: None, // swept with the default config anyway
                quorum: None,
                telemetry: false,
                patterns: vec![FaultPattern::OneShot {
                    at: 1.5,
                    nic: 0,
                    action: FaultAction::FailNic,
                }],
            },
            FaultScenario {
                name: "sweep-b".into(),
                seed: 12,
                iters: 3,
                workload: Workload::Serving { prompt_tokens: 2000 },
                max_overhead: None,
                cluster: None,
                recovery: Some(RecoveryConfig { checkpoint_interval: 2, ..Default::default() }),
                quorum: None,
                telemetry: false,
                patterns: vec![],
            },
        ]
    }

    #[test]
    fn sweep_covers_every_scenario_in_order() {
        let corpus = corpus();
        let rows = recovery_sweep(&corpus, &Preset::testbed(), 1);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].scenario, "sweep-a");
        assert_eq!(rows[1].scenario, "sweep-b");
        // Every row carries all four arms with the GPU-hours metric.
        for row in &rows {
            assert_eq!(row.compare.lossless.arm, "lossless");
            assert_eq!(row.compare.elastic.arm, "elastic_shrink");
            assert_eq!(row.compare.checkpoint.arm, "checkpoint_restart");
            assert_eq!(row.compare.fast.arm, "fast_failover");
            assert!(row.compare.checkpoint.gpu_hours_wasted >= 0.0);
        }
        // The fault-carrying training scenario shows the paper ordering.
        assert!(rows[0].compare.speedup_vs_checkpoint.unwrap() > 1.0);
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let corpus = corpus();
        let serial = recovery_sweep(&corpus, &Preset::testbed(), 1);
        let parallel = recovery_sweep(&corpus, &Preset::testbed(), 4);
        let js = recovery_sweep_to_json(&serial).pretty();
        let jp = recovery_sweep_to_json(&parallel).pretty();
        assert_eq!(js, jp, "sweep JSON must be bit-identical at any thread count");
        assert!(js.contains("\"scenarios\""));
        assert!(js.contains("\"speedup_vs_checkpoint\""));
        assert!(js.contains("\"elastic_shrink\""));
    }
}
