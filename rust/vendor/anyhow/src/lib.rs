//! Minimal offline stand-in for the `anyhow` crate. The build image has no
//! crates.io access (the same constraint that motivated the vendored
//! `rand`/`serde`/`clap`/`proptest` replacements in `r2ccl::util`), so this
//! crate implements exactly the subset of the anyhow API the repository
//! uses: [`Error`], [`Result`], [`anyhow!`], [`ensure!`] and [`Context`].

use std::fmt;

/// A flattened, string-carrying error.
///
/// Deliberately does **not** implement `std::error::Error`: that keeps the
/// blanket `From` impl below coherent with `impl<T> From<T> for T` — the
/// same trick the real crate uses.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible computation's error.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return an `Err` when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn ensure_and_anyhow_roundtrip() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
        let e2 = anyhow!("x = {}", 3);
        assert_eq!(format!("{e2}"), "x = 3");
    }

    #[test]
    fn context_wraps_std_errors() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file: "));
        let r2: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e2 = r2.with_context(|| format!("step {}", 4)).unwrap_err();
        assert!(e2.to_string().contains("step 4"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
